"""Logical-axis → mesh-axis sharding rules.

Model code annotates every parameter with logical axis names
(``("embed", "ffn")`` etc. — see models/layers.py); this module turns those
into concrete ``PartitionSpec``s for a given mesh, applying:

  * **TP** — ``ffn``/``heads``/``kv_heads``/``inner``/``vocab`` →  ``model``
  * **EP** — ``experts`` → ``model`` (expert parallelism shares the TP axis;
    within an expert the ffn axis is then unsharded)
  * **FSDP/ZeRO-3** — ``embed`` → ``data`` (+ ``pod``): parameters and
    optimizer state sharded over the data axes, all-gathered per layer by
    GSPMD. This is what makes arctic-480b's optimizer state fit.
  * divisibility guard — an axis is only sharded if its size divides the
    mesh axis size; otherwise it stays replicated (e.g. whisper's 6 heads
    on a 16-wide model axis: the fused head*dim columns shard instead).

Batch/sequence rules for activations live in ``input_specs`` (launch/).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis → preferred mesh axes, in priority order. The special axis
# name tuples ("pod","data") merge into one sharding tuple entry.
DEFAULT_RULES: Dict[str, Any] = {
    "vocab": "model",
    "embed": ("pod", "data"),   # FSDP
    "ffn": "model",
    "heads": "model",
    "kv_heads": "model",
    "inner": "model",
    "experts": "model",
    "layers": None,             # scan axis — never sharded
    "conv_k": None,
}

# ZeRO-1: parameters replicated across the data axes (TP-sharding only);
# optimizer moments keep the DEFAULT_RULES (data-sharded). Removes the
# per-layer FSDP parameter all-gathers — the right trade whenever the
# TP-sharded fp32 params + grads fit in HBM (§Perf hillclimb; selection is
# the paper's discriminant applied to distribution algorithms).
ZERO1_PARAM_RULES: Dict[str, Any] = dict(DEFAULT_RULES, embed=None)


def pick_param_policy(n_params: int, mesh, hbm_bytes: int = 16 * 2 ** 30,
                      dtype_bytes: int = 4) -> str:
    """``auto`` policy: zero1 iff fp32 params + fp32 grads on one TP shard
    stay under half the HBM budget (leaving room for moments slices,
    bf16 working copies and activations)."""
    tp = int(mesh.shape.get("model", 1))
    per_dev = n_params * dtype_bytes * 2 / tp      # params + grads
    return "zero1" if per_dev <= hbm_bytes / 2 else "fsdp"


def rules_for(policy: str) -> Dict[str, Any]:
    if policy == "zero1":
        return ZERO1_PARAM_RULES
    return DEFAULT_RULES


def _mesh_axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(
            __import__("math").prod(
                mesh.shape[a] for a in axis if a in mesh.shape))
    return int(mesh.shape.get(axis, 1))


def _present(mesh: Mesh, axis):
    """Filter rule axes down to those present in the mesh."""
    if axis is None:
        return None
    if isinstance(axis, tuple):
        axes = tuple(a for a in axis if a in mesh.shape)
        if not axes:
            return None
        return axes if len(axes) > 1 else axes[0]
    return axis if axis in mesh.shape else None


def spec_for(axes: Tuple[str, ...], shape: Tuple[int, ...], mesh: Mesh,
             rules: Optional[Dict[str, Any]] = None) -> P:
    """PartitionSpec for one array given its logical axes and shape."""
    rules = rules or DEFAULT_RULES
    used = set()
    entries = []
    for name, dim in zip(axes, shape):
        target = _present(mesh, rules.get(name))
        size = _mesh_axis_size(mesh, target)
        flat = (target if isinstance(target, tuple)
                else (target,) if target else ())
        if (target is None or dim % max(size, 1) != 0
                or any(a in used for a in flat)):
            entries.append(None)
        else:
            entries.append(target)
            used.update(flat)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def params_specs(axes_tree: Any, params_shapes: Any, mesh: Mesh,
                 rules: Optional[Dict[str, Any]] = None) -> Any:
    """PartitionSpec pytree matching a params pytree.

    ``axes_tree`` — logical-axis pytree from model init;
    ``params_shapes`` — params pytree (arrays or ShapeDtypeStructs).
    """
    def is_axes_leaf(x):
        return isinstance(x, tuple) and all(isinstance(s, str) for s in x)

    flat_axes = jax.tree.flatten(axes_tree, is_leaf=is_axes_leaf)
    flat_shapes = jax.tree.flatten(params_shapes)
    specs = [
        spec_for(a, tuple(s.shape), mesh, rules)
        for a, s in zip(flat_axes[0], flat_shapes[0])
    ]
    return jax.tree.unflatten(flat_shapes[1], specs)


def shardings_of(specs: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))


def batch_spec(mesh: Mesh) -> P:
    """Data-parallel batch sharding over (pod, data)."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    return P(axes if len(axes) > 1 else (axes[0] if axes else None))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
