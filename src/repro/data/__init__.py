"""Data pipeline: deterministic synthetic LM batches + memmap token stores,
host-sharded by data-parallel rank, with background prefetch."""

from . import pipeline

__all__ = ["pipeline"]
