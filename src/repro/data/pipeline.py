"""Data pipeline: synthetic + memmap token sources, host sharding, prefetch.

Design constraints at 1000-node scale:
  * **Determinism under restart/elasticity** — a batch is a pure function of
    (seed, step, dp_rank, dp_size); after a failure, the restored step
    counter alone reproduces the exact stream, and a *re-meshed* job (new
    dp_size) keeps per-sample determinism because sample ids are global.
  * **Host sharding** — each host materializes only its dp-rank slice.
  * **Prefetch** — a daemon thread keeps ``depth`` batches ahead so host
    data work overlaps device compute.

Two sources: ``SyntheticLM`` (zipfian tokens; CI and dry-run) and
``MemmapLM`` (np.memmap over a packed uint32 token file; production-shaped
I/O path with the same determinism contract).
"""

from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


class SyntheticLM:
    """Zipf-distributed token batches with next-token labels."""

    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 dp_rank: int = 0, dp_size: int = 1, seed: int = 0,
                 zipf_a: float = 1.2,
                 extra_specs: Optional[Dict[str, Tuple]] = None):
        assert global_batch % dp_size == 0
        self.vocab = vocab
        self.seq = seq_len
        self.local_batch = global_batch // dp_size
        self.global_batch = global_batch
        self.dp_rank = dp_rank
        self.dp_size = dp_size
        self.seed = seed
        self.zipf_a = zipf_a
        self.extra_specs = extra_specs or {}

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        out: Dict[str, np.ndarray] = {}
        toks = np.empty((self.local_batch, self.seq + 1), dtype=np.int32)
        for i in range(self.local_batch):
            gid = step * self.global_batch \
                + self.dp_rank * self.local_batch + i
            rng = np.random.default_rng(
                np.random.SeedSequence([self.seed, gid]))
            z = rng.zipf(self.zipf_a, size=self.seq + 1)
            toks[i] = (z - 1) % self.vocab
        out["tokens"] = toks[:, :-1]
        out["labels"] = toks[:, 1:]
        for name, (shape, dtype) in self.extra_specs.items():
            rng = np.random.default_rng(
                np.random.SeedSequence([self.seed, step, hash(name) % 2**31]))
            out[name] = rng.standard_normal(
                (self.local_batch,) + tuple(shape)).astype(dtype)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class MemmapLM:
    """Packed-token memmap source with the same determinism contract."""

    def __init__(self, path: str, vocab: int, seq_len: int,
                 global_batch: int, dp_rank: int = 0, dp_size: int = 1,
                 seed: int = 0):
        self.tokens = np.memmap(path, dtype=np.uint32, mode="r")
        self.vocab = vocab
        self.seq = seq_len
        assert global_batch % dp_size == 0
        self.local_batch = global_batch // dp_size
        self.global_batch = global_batch
        self.dp_rank = dp_rank
        self.seed = seed
        self.n_windows = (len(self.tokens) - 1) // seq_len

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        toks = np.empty((self.local_batch, self.seq + 1), dtype=np.int32)
        for i in range(self.local_batch):
            gid = step * self.global_batch \
                + self.dp_rank * self.local_batch + i
            rng = np.random.default_rng(
                np.random.SeedSequence([self.seed, gid]))
            w = int(rng.integers(0, self.n_windows))
            start = w * self.seq
            toks[i] = self.tokens[start: start + self.seq + 1] % self.vocab
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch with bounded depth; `.close()` to stop."""

    _SENTINEL = object()

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.source.batch_at(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self):
        while True:
            try:
                return self.q.get(timeout=0.5)
            except queue.Empty:
                if self._stop.is_set():
                    raise StopIteration

    def __iter__(self):
        return self

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2.0)
