"""Runtime supervision: bounded-restart supervisor, straggler monitor,
drainable background workers, heartbeat failure detection."""

from . import supervisor
from .supervisor import (
    BackgroundWorker,
    Heartbeat,
    RestartPolicy,
    StragglerMonitor,
    Supervisor,
)

__all__ = ["supervisor", "BackgroundWorker", "Heartbeat", "RestartPolicy",
           "StragglerMonitor", "Supervisor"]
