"""Runtime supervision: bounded-restart supervisor, straggler monitor,
heartbeat failure detection."""

from . import supervisor
from .supervisor import Heartbeat, RestartPolicy, StragglerMonitor, Supervisor

__all__ = ["supervisor", "Heartbeat", "RestartPolicy", "StragglerMonitor",
           "Supervisor"]
