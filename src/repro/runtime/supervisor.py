"""Run supervisor: bounded restarts, stragglers, background workers.

At 1000-node scale the training loop is wrapped by a supervisor that (a)
restarts the step loop from the latest checkpoint on worker failure, (b)
watches step-time statistics for stragglers, and (c) coordinates elastic
re-mesh on topology change. The serving stack reuses the same primitives:
the planner's async refinement worker (:mod:`repro.serve.plan_cache`) is
a :class:`BackgroundWorker`, and decode-step latency feeds a
:class:`StragglerMonitor`. None of these need real TPUs to be engineered
and unit-tested:

  * :class:`Supervisor` — run(fn) with bounded restarts and exponential
    backoff; failure injection in tests exercises the restart path.
  * :class:`BackgroundWorker` — drainable daemon loop around a ``step()``
    callable; ``stop(drain=True)`` keeps stepping until the work source
    reports empty, then joins — the graceful-shutdown contract the
    refinement worker relies on (production timings queued before
    shutdown are folded into the profile, not dropped).
  * :class:`StragglerMonitor` — EMA of step wall time; flags steps slower
    than ``threshold ×`` the EMA. On a real deployment the flag feeds the
    re-mesh decision (drop the slow host, restore on the smaller mesh via
    checkpoint/store's elastic restore).
  * :class:`Heartbeat` — thread that would publish liveness to the job
    coordinator; here it records last-beat timestamps so tests can assert
    the failure-detection contract (miss N beats → declared dead).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, List, Optional


@dataclasses.dataclass
class RestartPolicy:
    max_restarts: int = 5
    backoff_s: float = 1.0
    backoff_mult: float = 2.0
    max_backoff_s: float = 60.0


class Supervisor:
    def __init__(self, policy: Optional[RestartPolicy] = None,
                 sleep=time.sleep):
        self.policy = policy or RestartPolicy()
        self.restarts = 0
        self.failures: List[BaseException] = []
        self._sleep = sleep

    def run(self, fn: Callable[[int], Any]) -> Any:
        """Run ``fn(attempt)`` until success or restart budget exhausted.

        ``fn`` is expected to restore from the latest checkpoint itself
        (the train loop does), so supervisor restarts lose at most the
        steps since the last save.
        """
        backoff = self.policy.backoff_s
        attempt = 0
        while True:
            try:
                return fn(attempt)
            except KeyboardInterrupt:
                raise
            except BaseException as e:
                self.failures.append(e)
                self.restarts += 1
                if self.restarts > self.policy.max_restarts:
                    raise RuntimeError(
                        f"restart budget exhausted after "
                        f"{self.policy.max_restarts} restarts"
                    ) from e
                self._sleep(backoff)
                backoff = min(backoff * self.policy.backoff_mult,
                              self.policy.max_backoff_s)
                attempt += 1


class BackgroundWorker:
    """Drainable daemon loop around a ``step()`` callable.

    ``step()`` performs one unit of work and returns truthy, or returns
    falsy when its work source is empty — the worker then parks on an
    event until :meth:`notify` (producers call it after enqueueing) or
    the idle poll interval elapses.

    Shutdown contract (what the plan-cache refinement worker needs):

    * ``stop(drain=True)`` — graceful: the loop keeps calling ``step()``
      until it reports idle, then exits; ``stop`` joins the thread. With
      producers quiesced first, this is a *deterministic* drain — every
      item enqueued before the call is processed before ``stop`` returns.
    * ``stop(drain=False)`` — prompt: the loop exits before the next
      ``step()``; unprocessed items stay in the owner's queue.

    Exceptions from ``step()`` are counted (``errors``), reported to
    ``on_error`` and treated as one unit of work — a poisoned item must
    not wedge the drain. The worker never re-raises into the owner.
    """

    def __init__(self, step: Callable[[], Any], name: str = "bg-worker",
                 idle_wait_s: float = 0.05,
                 on_error: Optional[Callable[[BaseException], Any]] = None):
        self._step = step
        self._name = name
        self._idle_wait = idle_wait_s
        self._on_error = on_error
        self._wake = threading.Event()
        self._stop_evt = threading.Event()
        self._drain = True
        self._thread: Optional[threading.Thread] = None
        self.steps = 0
        self.errors = 0

    def start(self) -> "BackgroundWorker":
        if self._thread is not None and self._thread.is_alive():
            return self
        self._stop_evt.clear()
        self._wake.clear()
        self._thread = threading.Thread(target=self._run, name=self._name,
                                        daemon=True)
        self._thread.start()
        return self

    def _run(self) -> None:
        while True:
            if self._stop_evt.is_set() and not self._drain:
                return
            try:
                did = bool(self._step())
            except Exception as e:  # noqa: BLE001 — isolate the owner
                self.errors += 1
                did = True
                if self._on_error is not None:
                    self._on_error(e)
            if did:
                self.steps += 1
                continue
            if self._stop_evt.is_set():
                return  # stopping + idle == drained
            self._wake.wait(self._idle_wait)
            self._wake.clear()

    def notify(self) -> None:
        """Wake the worker (a producer enqueued work)."""
        self._wake.set()

    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def stop(self, drain: bool = True, timeout: float = 10.0) -> bool:
        """Stop the loop; returns True iff the thread exited in time."""
        self._drain = bool(drain)
        self._stop_evt.set()
        self._wake.set()
        t = self._thread
        if t is None:
            return True
        t.join(timeout)
        if t.is_alive():
            return False
        self._thread = None
        return True


class StragglerMonitor:
    """EMA step-time watchdog."""

    def __init__(self, alpha: float = 0.1, threshold: float = 2.0,
                 warmup_steps: int = 5):
        self.alpha = alpha
        self.threshold = threshold
        self.warmup = warmup_steps
        self.ema: Optional[float] = None
        self.n = 0
        self.flagged: List[int] = []

    def observe(self, step: int, wall_s: float) -> bool:
        """Record one step; returns True if flagged as straggler."""
        self.n += 1
        if self.ema is None:
            self.ema = wall_s
            return False
        is_slow = (self.n > self.warmup
                   and wall_s > self.threshold * self.ema)
        if is_slow:
            self.flagged.append(step)
        else:
            # stragglers don't poison the EMA
            self.ema = (1 - self.alpha) * self.ema + self.alpha * wall_s
        return is_slow


class Heartbeat:
    """Liveness publisher + failure detector (local, test-oriented)."""

    def __init__(self, interval_s: float = 1.0, miss_limit: int = 3):
        self.interval = interval_s
        self.miss_limit = miss_limit
        self.last_beat: Optional[float] = None
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def start(self) -> None:
        def run():
            while not self._stop.is_set():
                self.last_beat = time.monotonic()
                self._stop.wait(self.interval)
        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=2.0)

    def is_alive(self, now: Optional[float] = None) -> bool:
        if self.last_beat is None:
            return False
        now = now if now is not None else time.monotonic()
        return (now - self.last_beat) < self.interval * self.miss_limit
