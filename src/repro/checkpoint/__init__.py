"""Fault tolerance: sharded atomic checkpoints with mesh metadata, async
save manager, retention, preemption hook, elastic restore."""

from . import manager, store
from .manager import CheckpointManager

__all__ = ["manager", "store", "CheckpointManager"]
