"""Sharded checkpoint store: npz-per-leaf + JSON manifest, mesh-agnostic.

Orbax is unavailable offline, so this is a from-scratch store with the
properties that matter at scale:

  * **Sharded, resumable layout** — every pytree leaf is its own ``.npy``
    file under the step directory; a crashed save never corrupts previous
    steps (writes go to ``step_N.tmp`` then a single atomic rename).
  * **Mesh metadata** — the manifest records the mesh shape and per-leaf
    PartitionSpecs at save time; restore reshards to *any* new mesh
    (elastic scaling: the restore path device_puts each leaf with the new
    sharding — GSPMD reshards on first use).
  * **Integrity** — per-leaf byte sizes + dtype recorded and verified on
    load; manifest is written last so a directory missing a manifest is
    by definition incomplete and ignored by ``latest_step``.

On a multi-host deployment each host would write only its addressable
shards; this single-process container writes full arrays (noted in
DESIGN.md §8) — the layout and manifest format already carry everything
the multi-host writer needs.
"""

from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

MANIFEST = "manifest.json"
_STEP_RE = re.compile(r"^step_(\d+)$")


def _leaf_paths(tree: Any) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(_key_str(k) for k in path)
        out.append((name, leaf))
    return out


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    if hasattr(k, "name"):
        return str(k.name)
    return str(k)


def save(directory: str, step: int, tree: Any,
         specs: Optional[Any] = None,
         mesh_shape: Optional[Dict[str, int]] = None) -> str:
    """Atomic checkpoint save; returns the final step directory."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves = _leaf_paths(tree)
    spec_map: Dict[str, Any] = {}
    if specs is not None:
        for (name, _), (_, spec) in zip(
                leaves, _leaf_paths_specs(specs)):
            spec_map[name] = _spec_to_json(spec)

    entries = []
    for name, leaf in leaves:
        if leaf is None:
            entries.append({"name": name, "none": True})
            continue
        arr = np.asarray(jax.device_get(leaf))
        logical_dtype = str(arr.dtype)
        # numpy serializes ml_dtypes (bfloat16, float8_*) as raw void;
        # store bit-identical integer views + the logical dtype name.
        if arr.dtype.kind == "V" or logical_dtype not in np.sctypeDict:
            arr = arr.view({1: np.uint8, 2: np.uint16,
                            4: np.uint32}[arr.dtype.itemsize])
        fn = name.replace("/", ".") + ".npy"
        np.save(os.path.join(tmp, fn), arr)
        entries.append({
            "name": name, "file": fn, "dtype": logical_dtype,
            "shape": list(arr.shape), "bytes": int(arr.nbytes),
            "spec": spec_map.get(name),
        })
    manifest = {
        "step": step,
        "mesh_shape": mesh_shape or {},
        "leaves": entries,
        "format": 1,
    }
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def _leaf_paths_specs(specs: Any):
    from jax.sharding import PartitionSpec as P
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    return flat


def _spec_to_json(spec) -> Optional[List]:
    from jax.sharding import PartitionSpec as P
    if not isinstance(spec, P):
        return None
    out = []
    for e in spec:
        if e is None:
            out.append(None)
        elif isinstance(e, tuple):
            out.append(list(e))
        else:
            out.append(e)
    return out


def _json_to_spec(entry):
    from jax.sharding import PartitionSpec as P
    if entry is None:
        return P()
    return P(*[tuple(e) if isinstance(e, list) else e for e in entry])


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for d in os.listdir(directory):
        m = _STEP_RE.match(d)
        if m and os.path.exists(os.path.join(directory, d, MANIFEST)):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore(directory: str, step: int, like: Any,
            mesh=None, specs: Optional[Any] = None) -> Any:
    """Restore into the structure of ``like`` (pytree of arrays or
    ShapeDtypeStructs). If ``mesh``+``specs`` given (or saved specs exist),
    leaves are device_put with NamedShardings on the *current* mesh —
    elastic restore onto a different topology than the one that saved.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    d = os.path.join(directory, f"step_{step}")
    with open(os.path.join(d, MANIFEST)) as f:
        manifest = json.load(f)
    by_name = {e["name"]: e for e in manifest["leaves"]}
    leaves = _leaf_paths(like)
    spec_leaves = None
    if specs is not None:
        spec_leaves = {name: spec for (name, spec) in
                       [(n, s) for (n, s) in
                        [(nm, sp) for (nm, _), (_, sp) in
                         zip(leaves, _leaf_paths_specs(specs))]]}

    out = []
    for name, leaf in leaves:
        e = by_name.get(name)
        if e is None:
            raise KeyError(f"checkpoint missing leaf {name!r}")
        if e.get("none"):
            out.append(None)
            continue
        arr = np.load(os.path.join(d, e["file"]))
        if str(arr.dtype) != e["dtype"]:
            # integer-view round trip for ml_dtypes (bfloat16, fp8, ...)
            arr = arr.view(_resolve_dtype(e["dtype"]))
        if list(arr.shape) != e["shape"] or str(arr.dtype) != e["dtype"]:
            raise ValueError(f"integrity failure for {name}: manifest says "
                             f"{e['shape']}/{e['dtype']}, file has "
                             f"{arr.shape}/{arr.dtype}")
        if leaf is not None and tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch restoring {name}: checkpoint "
                f"{arr.shape} vs target {leaf.shape}")
        if mesh is not None:
            if spec_leaves is not None and name in spec_leaves:
                spec = spec_leaves[name]
            elif e.get("spec") is not None:
                spec = _json_to_spec(e["spec"])
                # Drop mesh axes that no longer exist (elastic re-mesh).
                spec = P(*[
                    ax if _axes_in_mesh(ax, mesh) else None for ax in spec])
            else:
                spec = P()
            out.append(jax.device_put(arr, NamedSharding(mesh, spec)))
        else:
            out.append(jnp.asarray(arr))
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, out)


def _resolve_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _axes_in_mesh(ax, mesh) -> bool:
    if ax is None:
        return True
    axes = ax if isinstance(ax, tuple) else (ax,)
    return all(a in mesh.shape for a in axes)


def retain(directory: str, keep: int) -> None:
    """Delete all but the newest ``keep`` complete checkpoints."""
    if not os.path.isdir(directory):
        return
    steps = sorted(
        int(_STEP_RE.match(d).group(1))
        for d in os.listdir(directory)
        if _STEP_RE.match(d)
        and os.path.exists(os.path.join(directory, d, MANIFEST)))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{s}"),
                      ignore_errors=True)
