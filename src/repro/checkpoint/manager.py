"""Checkpoint manager: async saves, retention, preemption hooks.

Wraps :mod:`repro.checkpoint.store` with the operational behaviours a
long-running multi-pod job needs:

  * **Async save** — device arrays are fetched to host synchronously (cheap
    relative to a step) and serialized on a background thread, so the train
    loop resumes immediately; ``wait()`` drains before exit/restore.
  * **Retention** — keep the newest K checkpoints (+ optional "keep every
    N steps forever" for post-hoc evals).
  * **Preemption** — ``install_sigterm_hook`` registers a handler that
    requests an immediate save-and-exit at the next step boundary (the TPU
    preemption notice pattern).
  * **Elastic restore** — delegates to store.restore with the *current*
    mesh; a checkpoint written on a (16,16) mesh restores cleanly onto
    (2,16,16) or a single host.
"""

from __future__ import annotations

import os
import signal
import threading
from typing import Any, Optional

import jax

from . import store


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 keep_every: Optional[int] = None):
        self.directory = directory
        self.keep = keep
        self.keep_every = keep_every
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self.preempted = threading.Event()
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save --
    def save(self, step: int, tree: Any, specs: Optional[Any] = None,
             mesh=None, blocking: bool = False) -> None:
        self.wait()
        # Fetch to host on the caller thread (device buffers may be donated
        # right after); serialization happens in the background.
        host_tree = jax.tree.map(
            lambda x: None if x is None else jax.device_get(x), tree,
            is_leaf=lambda x: x is None)
        mesh_shape = (
            {k: int(v) for k, v in mesh.shape.items()} if mesh else None)

        def work():
            try:
                store.save(self.directory, step, host_tree, specs=specs,
                           mesh_shape=mesh_shape)
                self._retain(step)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        if blocking:
            work()
            self._raise_if_failed()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def _retain(self, just_saved: int) -> None:
        if self.keep_every:
            # never delete multiples of keep_every
            kept = [s for s in self._steps() if s % self.keep_every == 0]
        else:
            kept = []
        steps = [s for s in self._steps() if s not in kept]
        for s in steps[: -self.keep] if self.keep > 0 else []:
            import shutil
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)

    def _steps(self):
        out = []
        for d in os.listdir(self.directory):
            m = store._STEP_RE.match(d)
            if m and os.path.exists(
                    os.path.join(self.directory, d, store.MANIFEST)):
                out.append(int(m.group(1)))
        return sorted(out)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._error is not None:
            e, self._error = self._error, None
            raise RuntimeError("async checkpoint save failed") from e

    # ---------------------------------------------------------- restore --
    def latest_step(self) -> Optional[int]:
        return store.latest_step(self.directory)

    def restore(self, like: Any, step: Optional[int] = None, mesh=None,
                specs: Optional[Any] = None) -> Any:
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(
                f"no complete checkpoint under {self.directory}")
        return store.restore(self.directory, step, like, mesh=mesh,
                             specs=specs)

    # ------------------------------------------------------- preemption --
    def install_sigterm_hook(self) -> None:
        def handler(signum, frame):
            self.preempted.set()
        signal.signal(signal.SIGTERM, handler)
