"""Training loop substrate: jitted train step (grad accumulation, mixed
precision, remat) + checkpointed training loop."""

from . import train_step

__all__ = ["train_step"]
