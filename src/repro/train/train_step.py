"""The jitted training step: loss → grad → (optional accumulation,
compression) → optimizer update.

This function is what the multi-pod dry-run lowers: its HLO carries the
full collective schedule (gradient reduce across data/pod axes is implicit
in GSPMD's partitioning of the batch dimension; FSDP parameter all-gathers
come from the ``embed→data`` sharding rule).

Microbatching: ``accum_steps > 1`` splits the local batch and accumulates
grads in fp32 with a ``lax.scan`` (sequential; memory-bound shapes get the
remat+accum combination).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models import api
from repro.models.transformer import ModelConfig
from repro.optim import adamw, muon, schedule as sched


class TrainState(NamedTuple):
    params: Any
    opt: Any                 # AdamWState | MuonState
    step: jax.Array


def make_train_state(key, cfg: ModelConfig, optimizer: str = "adamw",
                     dtype=jnp.float32) -> Tuple[TrainState, Any]:
    params, axes = api.init(key, cfg, dtype)
    opt = muon.init(params) if optimizer == "muon" else adamw.init(params)
    return TrainState(params=params, opt=opt,
                      step=jnp.zeros((), jnp.int32)), axes


def _grads(cfg: ModelConfig, params, batch, accum_steps: int,
           compute_dtype):
    """Value-and-grad with optional microbatch accumulation."""
    cparams = jax.tree.map(
        lambda p: p.astype(compute_dtype)
        if p.dtype == jnp.float32 and p.ndim >= 2 else p, params)

    def loss_of(p, b):
        return api.loss_fn(p, cfg, b)

    if accum_steps <= 1:
        (loss, metrics), g = jax.value_and_grad(
            loss_of, has_aux=True)(cparams, batch)
        return loss, metrics, g

    def split(b):
        return jax.tree.map(
            lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                + x.shape[1:]), b)

    micro = split(batch)
    zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), cparams)

    def body(carry, mb):
        acc, ls = carry
        (loss, metrics), g = jax.value_and_grad(
            loss_of, has_aux=True)(cparams, mb)
        acc = jax.tree.map(
            lambda a, x: a + x.astype(jnp.float32) / accum_steps, acc, g)
        return (acc, ls + loss / accum_steps), metrics

    (g, loss), metrics = jax.lax.scan(
        body, (zero, jnp.zeros((), jnp.float32)), micro)
    metrics = jax.tree.map(lambda m: m[-1], metrics)
    return loss, metrics, g


def train_step(
    state: TrainState,
    batch: Dict[str, jax.Array],
    *,
    cfg: ModelConfig,
    optimizer: str = "adamw",
    peak_lr: float = 3e-4,
    warmup: int = 100,
    total_steps: int = 10000,
    schedule: str = "cosine",
    accum_steps: int = 1,
    compute_dtype=jnp.bfloat16,
    weight_decay: float = 0.1,
) -> Tuple[TrainState, Dict[str, jax.Array]]:
    loss, metrics, grads = _grads(cfg, state.params, batch, accum_steps,
                                  compute_dtype)
    lr = sched.SCHEDULES[schedule](state.step, peak_lr, warmup, total_steps)
    if optimizer == "muon":
        new_params, new_opt = muon.update(
            grads, state.opt, state.params, lr, weight_decay=weight_decay)
    else:
        new_params, new_opt = adamw.update(
            grads, state.opt, state.params, lr, weight_decay=weight_decay)
    gnorm = jnp.sqrt(sum(
        jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads)))
    out_metrics = {
        "loss": loss.astype(jnp.float32),
        "lr": lr,
        "grad_norm": gnorm,
        **{k: v.astype(jnp.float32) for k, v in metrics.items()},
    }
    return TrainState(params=new_params, opt=new_opt,
                      step=state.step + 1), out_metrics


def make_train_step(cfg: ModelConfig, **kw):
    """Bind static config; returns fn(state, batch) suitable for jax.jit."""
    return functools.partial(train_step, cfg=cfg, **kw)
