"""Checkpointed training loop: data prefetch → jitted step → async save.

Integrates every fault-tolerance substrate:
  * restore-from-latest on entry (so a Supervisor restart resumes),
  * async checkpoint every ``save_every`` steps + retention,
  * SIGTERM preemption → save + clean exit,
  * straggler monitor on step wall times,
  * deterministic data: batch index = restored step (pipeline.py contract).
"""

from __future__ import annotations

import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import Prefetcher
from repro.models.transformer import ModelConfig
from repro.runtime.supervisor import StragglerMonitor
from repro.train.train_step import TrainState, make_train_state, make_train_step


def train(
    cfg: ModelConfig,
    source,                       # data source with .batch_at(step)
    total_steps: int,
    *,
    ckpt_dir: Optional[str] = None,
    save_every: int = 50,
    keep: int = 3,
    optimizer: str = "adamw",
    peak_lr: float = 3e-4,
    warmup: int = 20,
    log_every: int = 10,
    seed: int = 0,
    mesh=None,
    donate: bool = True,
    fail_at_step: Optional[int] = None,   # test hook: inject a crash
    log_fn: Callable[[str], None] = print,
) -> TrainState:
    state, axes = make_train_state(jax.random.PRNGKey(seed), cfg,
                                   optimizer=optimizer)
    mgr = CheckpointManager(ckpt_dir, keep=keep) if ckpt_dir else None
    start_step = 0
    if mgr is not None:
        latest = mgr.latest_step()
        if latest is not None:
            state = mgr.restore(state, step=latest, mesh=mesh)
            start_step = int(jax.device_get(state.step))
            log_fn(f"[train] restored checkpoint at step {start_step}")
        mgr.install_sigterm_hook()

    step_fn = jax.jit(
        make_train_step(cfg, optimizer=optimizer, peak_lr=peak_lr,
                        warmup=warmup, total_steps=total_steps),
        donate_argnums=(0,) if donate else (),
    )
    monitor = StragglerMonitor()
    prefetch = Prefetcher(source, start_step=start_step)
    try:
        for step in range(start_step, total_steps):
            bstep, np_batch = next(prefetch)
            assert bstep == step, (bstep, step)
            batch = {k: jnp.asarray(v) for k, v in np_batch.items()}
            t0 = time.perf_counter()
            state, metrics = step_fn(state, batch)
            metrics = jax.device_get(metrics)
            wall = time.perf_counter() - t0
            slow = monitor.observe(step, wall)
            if step % log_every == 0 or step == total_steps - 1:
                log_fn(f"[train] step={step} loss={metrics['loss']:.4f} "
                       f"lr={metrics['lr']:.2e} "
                       f"gnorm={metrics['grad_norm']:.3f} "
                       f"wall={wall*1e3:.0f}ms"
                       + (" [straggler]" if slow else ""))
            if fail_at_step is not None and step == fail_at_step:
                raise RuntimeError(f"injected failure at step {step}")
            want_save = mgr is not None and (
                (step + 1) % save_every == 0
                or step == total_steps - 1
                or mgr.preempted.is_set())
            if want_save:
                mgr.save(int(jax.device_get(state.step)), state, mesh=mesh)
            if mgr is not None and mgr.preempted.is_set():
                log_fn(f"[train] preempted at step {step}; "
                       "checkpoint saved, exiting")
                break
        if mgr is not None:
            mgr.wait()
        return state
    finally:
        prefetch.close()
