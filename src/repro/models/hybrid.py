"""Hybrid Mamba2 + shared-attention assembly (zamba2 family).

Zamba2 interleaves Mamba2 blocks with a *shared* transformer block whose
parameters are reused at every application point (arXiv:2411.15242) —
depth-wise weight sharing keeps the parameter count near-pure-SSM while
restoring attention's associative recall. We reproduce that structure:
``n_layers`` Mamba2 blocks; after every ``attn_every`` of them, the single
shared attention+MLP block runs (with sliding-window attention so the
long_500k decode cell stays sub-quadratic).

Simplifications vs the HF implementation (noted per DESIGN.md §8):
zamba2's concatenated [hidden, embedding] input to the shared block and its
per-application LoRA deltas are omitted — the shared block reads the
hidden state directly.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.sharding.context import shard_seq

from . import attention, layers, scan_util, ssm as ssm_lib
from .attention import KVCache
from .layers import Axes, Params
from .ssm import SSMCache
from .transformer import ModelConfig, _logits


class HybridCaches(NamedTuple):
    ssm: SSMCache            # stacked (L, ...)
    shared_kv: KVCache       # stacked (n_attn, ...)


def n_shared_applications(cfg: ModelConfig) -> int:
    return cfg.n_layers // cfg.attn_every if cfg.attn_every else 0


def init(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32
         ) -> Tuple[Params, Axes]:
    assert cfg.family == "hybrid" and cfg.ssm is not None
    keys = jax.random.split(key, cfg.n_layers + 4)
    p: Params = {}
    a: Axes = {}
    p["embed"], a["embed"] = layers.embed_init(
        keys[0], cfg.padded_vocab, cfg.d_model, dtype)

    blocks, baxes = [], None
    for i in range(cfg.n_layers):
        bp: Params = {}
        ba: Axes = {}
        bp["pre_norm"], ba["pre_norm"] = layers.rmsnorm_init(
            cfg.d_model, dtype)
        bp["mixer"], ba["mixer"] = ssm_lib.init(keys[1 + i], cfg.ssm, dtype)
        blocks.append(bp)
        baxes = ba
    p["blocks"] = layers.stack_layers(blocks)
    a["blocks"] = layers.stacked_axes(baxes)

    # The single shared attention+MLP block.
    ks = jax.random.split(keys[-2], 3)
    sp: Params = {}
    sa: Axes = {}
    sp["pre_attn_norm"], sa["pre_attn_norm"] = layers.rmsnorm_init(
        cfg.d_model, dtype)
    sp["attn"], sa["attn"] = attention.init(ks[0], cfg.attn_cfg, dtype)
    sp["pre_mlp_norm"], sa["pre_mlp_norm"] = layers.rmsnorm_init(
        cfg.d_model, dtype)
    sp["mlp"], sa["mlp"] = layers.glu_mlp_init(
        ks[1], cfg.d_model, cfg.d_ff, dtype)
    p["shared"] = sp
    a["shared"] = sa
    p["final_norm"], a["final_norm"] = layers.rmsnorm_init(cfg.d_model, dtype)
    return p, a


def _shared_block_train(cfg: ModelConfig, sp: Params, x: jax.Array,
                        rope) -> jax.Array:
    acfg = cfg.attn_cfg._replace(window=cfg.shared_window)
    h = layers.rmsnorm(sp["pre_attn_norm"], x)
    x = x + attention.apply_train(sp["attn"], acfg, h, rope=rope)
    h = layers.rmsnorm(sp["pre_mlp_norm"], x)
    return shard_seq(x + layers.glu_mlp(sp["mlp"], h))


def apply_train(params: Params, cfg: ModelConfig, tokens: jax.Array
                ) -> Tuple[jax.Array, jax.Array]:
    x = layers.embed(params["embed"], tokens)
    s = x.shape[1]
    rope = layers.rope_frequencies(cfg.head_dim, s, cfg.rope_theta)
    k = cfg.attn_every or cfg.n_layers
    n_groups = cfg.n_layers // k
    rem = cfg.n_layers - n_groups * k
    grouped = jax.tree.map(
        lambda a: a[: n_groups * k].reshape((n_groups, k) + a.shape[1:]),
        params["blocks"])

    def ssm_block(x, bp):
        h = layers.rmsnorm(bp["pre_norm"], x)
        return shard_seq(x + ssm_lib.apply_train(bp["mixer"], cfg.ssm, h)), None

    from .transformer import _maybe_remat
    ssm_block = _maybe_remat(ssm_block, cfg.remat)

    def group_body(x, bps):
        x, _ = scan_util.scan(ssm_block, x, bps)
        x = _shared_block_train(cfg, params["shared"], x, rope)
        return x, None

    x, _ = scan_util.scan(group_body, x, grouped)
    if rem:
        tail = jax.tree.map(lambda a: a[-rem:], params["blocks"])
        x, _ = scan_util.scan(ssm_block, x, tail)
    logits = _logits(cfg, params, x)
    return logits, jnp.zeros((), jnp.float32)


def init_caches(cfg: ModelConfig, batch: int, max_s: int,
                dtype=jnp.bfloat16) -> HybridCaches:
    L = cfg.n_layers
    na = max(1, n_shared_applications(cfg))
    one_s = ssm_lib.init_cache(cfg.ssm, batch, dtype)
    ssm = jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (L,) + a.shape).copy(), one_s)
    # Shared attention: windowed KV cache — ring buffer of window size
    # bounds memory at 500k contexts.
    win = cfg.shared_window or max_s
    eff = min(win, max_s)
    one_kv = attention.init_cache(cfg.attn_cfg, batch, eff, dtype)
    kv = jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (na,) + a.shape).copy(), one_kv)
    return HybridCaches(ssm=ssm, shared_kv=kv)


def _shared_block_decode(cfg: ModelConfig, sp: Params, x, kv: KVCache, rope):
    """Decode through the shared block with a ring-buffer window cache."""
    acfg = cfg.attn_cfg._replace(window=cfg.shared_window)
    h = layers.rmsnorm(sp["pre_attn_norm"], x)
    b = h.shape[0]
    pos = jnp.broadcast_to(kv.length, (b, 1))
    q, k, v = attention._project_qkv(sp["attn"], acfg, h, pos, rope)
    size = kv.k.shape[1]
    slot = kv.length % size
    new_k = jax.lax.dynamic_update_slice(
        kv.k, k.astype(kv.k.dtype), (0, slot, 0, 0))
    new_v = jax.lax.dynamic_update_slice(
        kv.v, v.astype(kv.v.dtype), (0, slot, 0, 0))
    group = acfg.n_heads // acfg.n_kv_heads
    scale = acfg.head_dim ** -0.5
    kq = jnp.repeat(new_k, group, axis=2)
    vq = jnp.repeat(new_v, group, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(kq.dtype), kq,
                        preferred_element_type=jnp.float32) * scale
    # Ring-buffer positions: slot s holds absolute position
    # length - ((slot - s) mod size); valid if within [0, length].
    slots = jnp.arange(size)
    age = (slot - slots) % size
    abs_pos = kv.length - age
    valid = (abs_pos >= 0) & (abs_pos <= kv.length)
    if cfg.shared_window:
        valid &= age < cfg.shared_window
    logits = jnp.where(valid[None, None, None, :], logits, -1e30)
    pattn = jax.nn.softmax(logits, axis=-1).astype(vq.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", pattn, vq)
    out = out.reshape(b, 1, acfg.n_heads * acfg.head_dim)
    x = x + layers.dense(sp["attn"]["wo"], out.astype(x.dtype))
    h = layers.rmsnorm(sp["pre_mlp_norm"], x)
    x = x + layers.glu_mlp(sp["mlp"], h)
    return x, KVCache(new_k, new_v, kv.length + 1)


def apply_decode(params: Params, cfg: ModelConfig, tokens: jax.Array,
                 caches: HybridCaches) -> Tuple[jax.Array, HybridCaches]:
    x = layers.embed(params["embed"], tokens)
    rope = layers.rope_frequencies(
        cfg.head_dim, cfg.max_seq, cfg.rope_theta)
    k = cfg.attn_every or cfg.n_layers
    n_groups = cfg.n_layers // k
    rem = cfg.n_layers - n_groups * k
    grouped_ssm = jax.tree.map(
        lambda a: a[: n_groups * k].reshape((n_groups, k) + a.shape[1:]),
        caches.ssm)
    grouped_blocks = jax.tree.map(
        lambda a: a[: n_groups * k].reshape((n_groups, k) + a.shape[1:]),
        params["blocks"])

    def ssm_block(x, sl):
        bp, sc = sl
        h = layers.rmsnorm(bp["pre_norm"], x)
        out, sc2 = ssm_lib.apply_decode(bp["mixer"], cfg.ssm, h, sc)
        return x + out, sc2

    new_kvs = []
    xs = x
    new_ssm_groups = []
    for gi in range(n_groups):
        bps = jax.tree.map(lambda a: a[gi], grouped_blocks)
        scs = jax.tree.map(lambda a: a[gi], grouped_ssm)
        xs, sc2 = scan_util.scan(ssm_block, xs, (bps, scs))
        new_ssm_groups.append(sc2)
        kv = jax.tree.map(lambda a: a[gi], caches.shared_kv)
        xs, kv2 = _shared_block_decode(cfg, params["shared"], xs, kv, rope)
        new_kvs.append(kv2)
    new_ssm = jax.tree.map(lambda *xs_: jnp.concatenate(xs_, axis=0),
                           *new_ssm_groups)
    if rem:
        tail_b = jax.tree.map(lambda a: a[-rem:], params["blocks"])
        tail_c = jax.tree.map(lambda a: a[-rem:], caches.ssm)
        xs, sc2 = scan_util.scan(ssm_block, xs, (tail_b, tail_c))
        new_ssm = jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=0),
                               new_ssm, sc2)
    new_kv = jax.tree.map(lambda *xs_: jnp.stack(xs_, axis=0), *new_kvs)
    logits = _logits(cfg, params, xs)
    return logits, HybridCaches(ssm=new_ssm, shared_kv=new_kv)
