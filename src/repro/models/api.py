"""Family-dispatching facade: one (init, train, prefill, decode) API for
every assigned architecture. The launcher, dry-run, trainer and server all
go through these four functions and never inspect the family themselves.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import encdec, hybrid, transformer
from .layers import Axes, Params
from .transformer import ModelConfig


def init(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32
         ) -> Tuple[Params, Axes]:
    if cfg.family == "encdec":
        return encdec.init(key, cfg, dtype)
    if cfg.family == "hybrid":
        return hybrid.init(key, cfg, dtype)
    return transformer.init(key, cfg, dtype)


def forward_train(params: Params, cfg: ModelConfig, batch: Dict[str, Any]
                  ) -> Tuple[jax.Array, jax.Array]:
    """batch → (logits fp32, aux_loss). Batch keys per family:
    tokens (B,S); encdec adds frames (B,S_enc,d); vlm adds
    vision_embeds (B,P,d)."""
    if cfg.family == "encdec":
        return encdec.apply_train(params, cfg, batch["tokens"],
                                  batch["frames"])
    if cfg.family == "hybrid":
        return hybrid.apply_train(params, cfg, batch["tokens"])
    prefix = batch.get("vision_embeds") if cfg.family == "vlm" else None
    return transformer.apply_train(params, cfg, batch["tokens"],
                                   prefix_embeds=prefix)


def loss_fn(params: Params, cfg: ModelConfig, batch: Dict[str, Any],
            aux_weight: float = 0.01) -> Tuple[jax.Array, Dict[str, Any]]:
    """Next-token cross-entropy (+ MoE aux)."""
    logits, aux = forward_train(params, cfg, batch)
    labels = batch["labels"]
    # VLM prefix positions carry no labels.
    if logits.shape[1] != labels.shape[1]:
        logits = logits[:, logits.shape[1] - labels.shape[1]:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones_like(nll)
    ce = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    total = ce + aux_weight * aux
    return total, {"loss": ce, "aux": aux}


def init_caches(params: Params, cfg: ModelConfig, batch: int, max_s: int,
                batch_inputs: Optional[Dict[str, Any]] = None,
                dtype=jnp.bfloat16):
    if cfg.family == "encdec":
        assert batch_inputs is not None and "frames" in batch_inputs
        return encdec.init_caches(params, cfg, batch_inputs["frames"],
                                  max_s, dtype)
    if cfg.family == "hybrid":
        return hybrid.init_caches(cfg, batch, max_s, dtype)
    return transformer.init_caches(cfg, batch, max_s, dtype)


def prefill(params: Params, cfg: ModelConfig, batch: Dict[str, Any],
            caches) -> Tuple[jax.Array, Any]:
    if cfg.family == "encdec":
        # Whisper prefill = decoding prompt tokens against encoder output;
        # teacher-forced path fills self-attention caches token by token in
        # serve.decode; here we return logits for the prompt.
        logits, _ = encdec.apply_train(params, cfg, batch["tokens"],
                                       batch["frames"])
        return logits, caches
    if cfg.family == "hybrid":
        raise NotImplementedError(
            "hybrid prefill runs through serve.decode chunked path")
    prefix = batch.get("vision_embeds") if cfg.family == "vlm" else None
    return transformer.apply_prefill(params, cfg, batch["tokens"], caches,
                                     prefix_embeds=prefix)


def decode_step(params: Params, cfg: ModelConfig, tokens: jax.Array,
                caches) -> Tuple[jax.Array, Any]:
    if cfg.family == "encdec":
        return encdec.apply_decode(params, cfg, tokens, caches)
    if cfg.family == "hybrid":
        return hybrid.apply_decode(params, cfg, tokens, caches)
    return transformer.apply_decode(params, cfg, tokens, caches)
