"""Encoder–decoder assembly (whisper-tiny backbone).

The audio frontend (log-mel + conv downsampling) is a STUB per the
assignment: ``input_specs`` provides precomputed frame embeddings
(B, n_frames, d) — the transformer backbone is what we build. Encoder
blocks are bidirectional (no mask, sinusoidal positions); decoder blocks
are causal self-attention + cross-attention + GELU MLP, exactly the
whisper layout.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.sharding.context import shard_seq

from . import attention, layers, scan_util
from .attention import KVCache
from .layers import Axes, Params
from .transformer import ModelConfig, _logits


class EncDecCaches(NamedTuple):
    self_kv: KVCache          # stacked (L, ...)
    cross_k: jax.Array        # (L, B, S_enc, Hkv, Dh)
    cross_v: jax.Array


def _sinusoid(n: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def init(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32
         ) -> Tuple[Params, Axes]:
    assert cfg.family == "encdec"
    nenc = cfg.encoder_layers
    keys = jax.random.split(key, nenc + cfg.n_layers + 4)
    p: Params = {}
    a: Axes = {}
    p["embed"], a["embed"] = layers.embed_init(
        keys[0], cfg.padded_vocab, cfg.d_model, dtype)

    acfg = cfg.attn_cfg

    enc_blocks, eaxes = [], None
    for i in range(nenc):
        ks = jax.random.split(keys[1 + i], 3)
        bp: Params = {}
        ba: Axes = {}
        bp["pre_attn_norm"], ba["pre_attn_norm"] = layers.rmsnorm_init(
            cfg.d_model, dtype)
        bp["attn"], ba["attn"] = attention.init(ks[0], acfg, dtype)
        bp["pre_mlp_norm"], ba["pre_mlp_norm"] = layers.rmsnorm_init(
            cfg.d_model, dtype)
        bp["mlp"], ba["mlp"] = layers.mlp_init(
            ks[1], cfg.d_model, cfg.d_ff, dtype)
        enc_blocks.append(bp)
        eaxes = ba
    p["encoder"] = layers.stack_layers(enc_blocks)
    a["encoder"] = layers.stacked_axes(eaxes)
    p["enc_norm"], a["enc_norm"] = layers.rmsnorm_init(cfg.d_model, dtype)

    dec_blocks, daxes = [], None
    for i in range(cfg.n_layers):
        ks = jax.random.split(keys[1 + nenc + i], 4)
        bp = {}
        ba = {}
        bp["pre_attn_norm"], ba["pre_attn_norm"] = layers.rmsnorm_init(
            cfg.d_model, dtype)
        bp["attn"], ba["attn"] = attention.init(ks[0], acfg, dtype)
        bp["pre_cross_norm"], ba["pre_cross_norm"] = layers.rmsnorm_init(
            cfg.d_model, dtype)
        bp["cross"], ba["cross"] = attention.init(ks[1], acfg, dtype)
        bp["pre_mlp_norm"], ba["pre_mlp_norm"] = layers.rmsnorm_init(
            cfg.d_model, dtype)
        bp["mlp"], ba["mlp"] = layers.mlp_init(
            ks[2], cfg.d_model, cfg.d_ff, dtype)
        dec_blocks.append(bp)
        daxes = ba
    p["decoder"] = layers.stack_layers(dec_blocks)
    a["decoder"] = layers.stacked_axes(daxes)
    p["final_norm"], a["final_norm"] = layers.rmsnorm_init(cfg.d_model, dtype)
    return p, a


def encode(params: Params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """frames: (B, S_enc, d) stub embeddings → encoder output."""
    s = frames.shape[1]
    x = frames + _sinusoid(s, cfg.d_model).astype(frames.dtype)[None]
    acfg = cfg.attn_cfg._replace(causal=False)

    def body(x, bp):
        h = layers.rmsnorm(bp["pre_attn_norm"], x)
        x = x + attention.apply_train(bp["attn"], acfg, h, rope=None)
        h = layers.rmsnorm(bp["pre_mlp_norm"], x)
        x = x + layers.mlp(bp["mlp"], h)
        return shard_seq(x), None

    x, _ = scan_util.scan(body, x, params["encoder"])
    return layers.rmsnorm(params["enc_norm"], x)


def apply_train(params: Params, cfg: ModelConfig, tokens: jax.Array,
                frames: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Teacher-forced training: (tokens (B,S_dec), frames (B,S_enc,d))."""
    enc = encode(params, cfg, frames)
    x = layers.embed(params["embed"], tokens)
    s = x.shape[1]
    rope = layers.rope_frequencies(cfg.head_dim, s, cfg.rope_theta)
    acfg = cfg.attn_cfg

    def body(x, bp):
        h = layers.rmsnorm(bp["pre_attn_norm"], x)
        x = x + attention.apply_train(bp["attn"], acfg, h, rope=rope)
        h = layers.rmsnorm(bp["pre_cross_norm"], x)
        ek, ev = attention.project_kv(bp["cross"], acfg, enc)
        x = x + attention.apply_cross(bp["cross"], acfg, h, ek, ev)
        h = layers.rmsnorm(bp["pre_mlp_norm"], x)
        x = x + layers.mlp(bp["mlp"], h)
        return x, None

    from .transformer import _maybe_remat
    x, _ = scan_util.scan(_maybe_remat(body, cfg.remat), x, params["decoder"])
    logits = _logits(cfg, params, x)
    return logits, jnp.zeros((), jnp.float32)


def init_caches(params: Params, cfg: ModelConfig, frames: jax.Array,
                max_s: int, dtype=jnp.bfloat16) -> EncDecCaches:
    """Run the encoder once, precompute cross K/V, allocate self caches."""
    enc = encode(params, cfg, frames)
    acfg = cfg.attn_cfg
    b = frames.shape[0]
    L = cfg.n_layers

    def kv_of_layer(bp):
        return attention.project_kv(bp["cross"], acfg, enc)

    cross = jax.lax.map(lambda bp: kv_of_layer(bp), params["decoder"])
    ck, cv = cross
    one = attention.init_cache(acfg, b, max_s, dtype)
    self_kv = jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (L,) + a.shape).copy(), one)
    return EncDecCaches(self_kv=self_kv, cross_k=ck.astype(dtype),
                        cross_v=cv.astype(dtype))


def apply_decode(params: Params, cfg: ModelConfig, tokens: jax.Array,
                 caches: EncDecCaches) -> Tuple[jax.Array, EncDecCaches]:
    x = layers.embed(params["embed"], tokens)
    acfg = cfg.attn_cfg
    max_s = caches.self_kv.k.shape[2]
    rope = layers.rope_frequencies(cfg.head_dim, max_s, cfg.rope_theta)

    def body(x, sl):
        bp, kv, ck, cv = sl
        h = layers.rmsnorm(bp["pre_attn_norm"], x)
        out, kv2 = attention.apply_decode(bp["attn"], acfg, h, kv, rope=rope)
        x = x + out
        h = layers.rmsnorm(bp["pre_cross_norm"], x)
        x = x + attention.apply_cross(bp["cross"], acfg, h,
                                      ck.astype(h.dtype), cv.astype(h.dtype))
        h = layers.rmsnorm(bp["pre_mlp_norm"], x)
        x = x + layers.mlp(bp["mlp"], h)
        return x, kv2

    x, new_kv = scan_util.scan(
        body, x,
        (params["decoder"], caches.self_kv, caches.cross_k, caches.cross_v))
    logits = _logits(cfg, params, x)
    return logits, EncDecCaches(self_kv=new_kv, cross_k=caches.cross_k,
                                cross_v=caches.cross_v)
