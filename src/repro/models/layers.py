"""Shared building blocks: init helpers, norms, MLPs, RoPE, embeddings.

Every ``init_*`` returns ``(params, axes)`` — two parallel pytrees, the
second holding logical axis-name tuples (e.g. ``("embed", "ffn")``) that
``repro.sharding.rules`` maps to mesh PartitionSpecs. This keeps sharding
policy out of model code entirely.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]
Axes = Dict[str, Any]


# ------------------------------------------------------------------ init ---

def dense_init(key: jax.Array, d_in: int, d_out: int,
               axes: Tuple[str, str], dtype=jnp.float32,
               scale: Optional[float] = None) -> Tuple[Params, Axes]:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    w = jax.random.normal(key, (d_in, d_out), dtype) * scale
    return {"w": w}, {"w": axes}


def embed_init(key: jax.Array, vocab: int, d: int,
               dtype=jnp.float32) -> Tuple[Params, Axes]:
    w = jax.random.normal(key, (vocab, d), dtype) * 0.02
    return {"w": w}, {"w": ("vocab", "embed")}


def rmsnorm_init(d: int, dtype=jnp.float32) -> Tuple[Params, Axes]:
    return {"g": jnp.ones((d,), dtype)}, {"g": ("embed",)}


# ------------------------------------------------------------- functions ---

def rmsnorm(params: Params, x: jax.Array, eps: float = 1e-6,
            plus_one: bool = False) -> jax.Array:
    """RMSNorm. ``plus_one=True`` uses the gemma convention g ← (1 + g)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    xn = xf * jax.lax.rsqrt(var + eps)
    g = params["g"].astype(jnp.float32)
    if plus_one:
        g = 1.0 + g
    return (xn * g).astype(dt)


def dense(params: Params, x: jax.Array) -> jax.Array:
    return x @ params["w"].astype(x.dtype)


def embed(params: Params, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["w"], tokens, axis=0)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    """Gemma-2 logit soft-capping; identity when cap <= 0."""
    if cap <= 0:
        return x
    return cap * jnp.tanh(x / cap)


# ----------------------------------------------------------------- RoPE ---

def rope_frequencies(dh: int, max_pos: int, theta: float = 10000.0):
    inv = 1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))
    pos = jnp.arange(max_pos, dtype=jnp.float32)
    ang = jnp.outer(pos, inv)               # (max_pos, dh/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array,
               positions: jax.Array) -> jax.Array:
    """x: (B, S, H, Dh); positions: (B, S) absolute positions."""
    dt = x.dtype
    c = cos[positions][:, :, None, :]        # (B, S, 1, Dh/2)
    s = sin[positions][:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(dt)


# ----------------------------------------------------------------- MLPs ---

def glu_mlp_init(key: jax.Array, d: int, d_ff: int,
                 dtype=jnp.float32) -> Tuple[Params, Axes]:
    k1, k2, k3 = jax.random.split(key, 3)
    p, a = {}, {}
    p["gate"], a["gate"] = dense_init(k1, d, d_ff, ("embed", "ffn"), dtype)
    p["up"], a["up"] = dense_init(k2, d, d_ff, ("embed", "ffn"), dtype)
    p["down"], a["down"] = dense_init(k3, d_ff, d, ("ffn", "embed"), dtype)
    return p, a


def glu_mlp(params: Params, x: jax.Array, activation: str = "silu"
            ) -> jax.Array:
    g = dense(params["gate"], x)
    u = dense(params["up"], x)
    act = jax.nn.silu if activation == "silu" else jax.nn.gelu
    return dense(params["down"], act(g) * u)


def mlp_init(key: jax.Array, d: int, d_ff: int,
             dtype=jnp.float32) -> Tuple[Params, Axes]:
    """Plain 2-layer MLP (whisper)."""
    k1, k2 = jax.random.split(key)
    p, a = {}, {}
    p["up"], a["up"] = dense_init(k1, d, d_ff, ("embed", "ffn"), dtype)
    p["down"], a["down"] = dense_init(k2, d_ff, d, ("ffn", "embed"), dtype)
    return p, a


def mlp(params: Params, x: jax.Array) -> jax.Array:
    return dense(params["down"], jax.nn.gelu(dense(params["up"], x)))


# ------------------------------------------------------------- stacking ---

def stack_layers(layer_params: list) -> Params:
    """Stack per-layer pytrees along axis 0 for lax.scan."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *layer_params)


def stacked_axes(axes: Axes) -> Axes:
    """Prefix every logical axis tuple with the scan 'layers' axis."""
    return jax.tree.map(
        lambda t: ("layers",) + tuple(t),
        axes,
        is_leaf=lambda t: isinstance(t, tuple) and all(
            isinstance(s, str) for s in t),
    )
