"""Decoder-LM assembly covering the dense / MoE / SSM / hybrid families.

One ``ModelConfig`` describes every assigned architecture; ``init`` builds a
stacked-parameter pytree and ``apply_train`` / ``apply_prefill`` /
``apply_decode`` run it with ``lax.scan`` over layers (O(1) compile cost in
depth — essential for the 80-layer dry-run cells).

Per-layer heterogeneity that is *data* (sliding-window size alternation in
gemma2) rides through the scan as a per-layer array; heterogeneity that is
*structural* (zamba2's periodic shared attention) is handled by
:mod:`repro.models.hybrid`.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding.context import shard_logits, shard_seq

from . import attention, layers, moe as moe_lib, scan_util, ssm as ssm_lib
from .attention import AttnConfig, KVCache
from .layers import Axes, Params
from .moe import MoEConfig
from .ssm import SSMCache, SSMConfig


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    vocab: int
    # attention (unused for pure-ssm archs)
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    activation: str = "silu"
    rope_theta: float = 10000.0
    final_softcap: float = 0.0
    attn_softcap: float = 0.0
    window_pattern: Tuple[int, ...] = ()   # cycled per layer; 0 = global
    post_norms: bool = False
    norm_plus_one: bool = False
    embed_scale: bool = False
    tied_embeddings: bool = True
    # moe
    moe: Optional[MoEConfig] = None
    dense_residual: bool = False
    # ssm / hybrid
    ssm: Optional[SSMConfig] = None
    attn_every: int = 0
    shared_attn: bool = False
    shared_window: int = 0
    # encdec
    encoder_layers: int = 0
    encoder_seq: int = 0
    # stubs
    vision_tokens: int = 0
    max_seq: int = 131072
    # activation rematerialization policy for the training path:
    # none | dots | full  (launch/train selects per shape cell)
    remat: str = "none"

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 128 when not divisible by 16:
        an unshardable LM head replicates (B,S,V) fp32 logits across the
        model axis — 12.3 GiB/device for mamba2's 50280 vocab (§Perf-3).
        Lookups never touch the pad rows; _logits masks the pad columns."""
        if self.vocab % 16 == 0:
            return self.vocab
        return ((self.vocab + 127) // 128) * 128

    @property
    def attn_cfg(self) -> AttnConfig:
        return AttnConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, head_dim=self.head_dim,
            rope_theta=self.rope_theta, logit_softcap=self.attn_softcap,
        )

    def layer_windows(self) -> jnp.ndarray:
        if not self.window_pattern:
            return jnp.zeros((self.n_layers,), jnp.int32)
        pat = list(self.window_pattern)
        reps = (self.n_layers + len(pat) - 1) // len(pat)
        return jnp.asarray((pat * reps)[: self.n_layers], jnp.int32)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks), for roofline
        MODEL_FLOPS = 6·N·D."""
        d = self.d_model
        n = 0
        n += self.vocab * d * (1 if self.tied_embeddings else 2)
        L = self.n_layers
        if self.family in ("dense", "moe", "encdec", "vlm"):
            attn = d * (self.n_heads + 2 * self.n_kv_heads) * self.head_dim \
                + self.n_heads * self.head_dim * d
            n += L * attn
        if self.family in ("dense", "encdec", "vlm"):
            gates = 3 if self.activation_is_glu else 2
            n += L * gates * d * self.d_ff
        if self.moe is not None:
            n += L * (d * self.moe.n_experts
                      + 3 * self.moe.n_experts * d * self.moe.d_ff)
            if self.dense_residual:
                n += L * 3 * d * self.d_ff
        if self.ssm is not None:
            s = self.ssm
            proj = 2 * s.d_inner + 2 * s.n_groups * s.d_state + s.n_heads
            ssm_l = d * proj + s.d_inner * d
            n_ssm_layers = L
            if self.family == "hybrid" and self.attn_every:
                pass  # all L layers are ssm; shared attn counted once below
            n += n_ssm_layers * ssm_l
        if self.shared_attn:
            n += d * (self.n_heads + 2 * self.n_kv_heads) * self.head_dim \
                + self.n_heads * self.head_dim * d + 3 * d * self.d_ff
        if self.encoder_layers:
            attn = d * (self.n_heads + 2 * self.n_kv_heads) * self.head_dim \
                + self.n_heads * self.head_dim * d
            n += self.encoder_layers * (attn + 2 * d * self.d_ff)
            # decoder cross-attention
            n += L * attn
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k experts only)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        n = self.param_count()
        n -= self.n_layers * 3 * self.moe.n_experts * d * self.moe.d_ff
        n += self.n_layers * 3 * self.moe.top_k * d * self.moe.d_ff
        return n

    @property
    def activation_is_glu(self) -> bool:
        return self.activation in ("silu", "gelu_glu")


# ------------------------------------------------------------------ init ---

def _init_block(key: jax.Array, cfg: ModelConfig, dtype) -> Tuple[Params, Axes]:
    """One decoder block (attention or ssm family)."""
    p: Params = {}
    a: Axes = {}
    ks = jax.random.split(key, 8)
    if cfg.family == "ssm":
        p["pre_norm"], a["pre_norm"] = layers.rmsnorm_init(cfg.d_model, dtype)
        p["mixer"], a["mixer"] = ssm_lib.init(ks[0], cfg.ssm, dtype)
        return p, a
    p["pre_attn_norm"], a["pre_attn_norm"] = layers.rmsnorm_init(
        cfg.d_model, dtype)
    p["attn"], a["attn"] = attention.init(ks[0], cfg.attn_cfg, dtype)
    p["pre_mlp_norm"], a["pre_mlp_norm"] = layers.rmsnorm_init(
        cfg.d_model, dtype)
    if cfg.post_norms:
        p["post_attn_norm"], a["post_attn_norm"] = layers.rmsnorm_init(
            cfg.d_model, dtype)
        p["post_mlp_norm"], a["post_mlp_norm"] = layers.rmsnorm_init(
            cfg.d_model, dtype)
    if cfg.moe is not None:
        p["moe"], a["moe"] = moe_lib.init(ks[1], cfg.moe, dtype)
        if cfg.dense_residual:
            p["mlp"], a["mlp"] = layers.glu_mlp_init(
                ks[2], cfg.d_model, cfg.d_ff, dtype)
    elif cfg.activation_is_glu:
        p["mlp"], a["mlp"] = layers.glu_mlp_init(
            ks[2], cfg.d_model, cfg.d_ff, dtype)
    else:
        p["mlp"], a["mlp"] = layers.mlp_init(
            ks[2], cfg.d_model, cfg.d_ff, dtype)
    return p, a


def init(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32
         ) -> Tuple[Params, Axes]:
    keys = jax.random.split(key, cfg.n_layers + 3)
    p: Params = {}
    a: Axes = {}
    p["embed"], a["embed"] = layers.embed_init(
        keys[0], cfg.padded_vocab, cfg.d_model, dtype)
    blocks = []
    baxes = None
    for i in range(cfg.n_layers):
        bp, baxes = _init_block(keys[1 + i], cfg, dtype)
        blocks.append(bp)
    p["blocks"] = layers.stack_layers(blocks)
    a["blocks"] = layers.stacked_axes(baxes)
    p["final_norm"], a["final_norm"] = layers.rmsnorm_init(cfg.d_model, dtype)
    if not cfg.tied_embeddings:
        p["lm_head"], a["lm_head"] = layers.dense_init(
            keys[-1], cfg.d_model, cfg.padded_vocab, ("embed", "vocab"),
            dtype)
    return p, a


# --------------------------------------------------------------- forward ---

def _block_apply_train(cfg: ModelConfig, bp: Params, x: jax.Array,
                       window: jax.Array, rope) -> Tuple[jax.Array, jax.Array]:
    """One block, training path. Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "ssm":
        h = layers.rmsnorm(bp["pre_norm"], x, plus_one=cfg.norm_plus_one)
        x = x + ssm_lib.apply_train(bp["mixer"], cfg.ssm, h)
        return shard_seq(x), aux
    h = layers.rmsnorm(bp["pre_attn_norm"], x, plus_one=cfg.norm_plus_one)
    acfg = cfg.attn_cfg._replace(window=int(window))
    attn_out = attention.apply_train(bp["attn"], acfg, h, rope=rope)
    if cfg.post_norms:
        attn_out = layers.rmsnorm(bp["post_attn_norm"], attn_out,
                                  plus_one=cfg.norm_plus_one)
    x = x + attn_out
    h = layers.rmsnorm(bp["pre_mlp_norm"], x, plus_one=cfg.norm_plus_one)
    if cfg.moe is not None:
        mo, a = moe_lib.apply(bp["moe"], cfg.moe, h)
        aux = aux + a
        if cfg.dense_residual:
            mo = mo + layers.glu_mlp(bp["mlp"], h, cfg.activation)
        mlp_out = mo
    elif cfg.activation_is_glu:
        act = "silu" if cfg.activation == "silu" else "gelu"
        mlp_out = layers.glu_mlp(bp["mlp"], h, act)
    else:
        mlp_out = layers.mlp(bp["mlp"], h)
    if cfg.post_norms:
        mlp_out = layers.rmsnorm(bp["post_mlp_norm"], mlp_out,
                                 plus_one=cfg.norm_plus_one)
    return shard_seq(x + mlp_out), aux


def _maybe_remat(body, remat: str):
    """Per-layer activation checkpointing around the scan body."""
    if remat == "none":
        return body
    if remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
    else:
        policy = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(body, policy=policy, prevent_cse=False)


def _rope_tables(cfg: ModelConfig, max_pos: int):
    if cfg.family == "ssm":
        return None
    return layers.rope_frequencies(cfg.head_dim, max_pos, cfg.rope_theta)


def _logits(cfg: ModelConfig, params: Params, x: jax.Array) -> jax.Array:
    x = layers.rmsnorm(params["final_norm"], x, plus_one=cfg.norm_plus_one)
    if cfg.tied_embeddings:
        w = params["embed"]["w"].astype(x.dtype)
        logits = x @ w.T
    else:
        logits = layers.dense(params["lm_head"], x)
    logits = shard_logits(logits)
    logits = layers.softcap(logits.astype(jnp.float32), cfg.final_softcap)
    if cfg.padded_vocab != cfg.vocab:
        # pad columns carry no probability mass
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
    return logits


def apply_train(params: Params, cfg: ModelConfig, tokens: jax.Array,
                prefix_embeds: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """tokens (B, S) → (logits (B, S', vocab) fp32, aux_loss).

    ``prefix_embeds`` (B, P, d) — VLM stub frontend: precomputed patch
    embeddings prepended to the token embeddings.
    """
    x = layers.embed(params["embed"], tokens)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    x = shard_seq(x)
    s = x.shape[1]
    rope = _rope_tables(cfg, s)
    windows = cfg.layer_windows()

    # Window sizes vary per layer (gemma2): the scan carries them as data,
    # but attention masks need static window values → group layers by
    # distinct window, scanning each homogeneous group.
    distinct = tuple(dict.fromkeys(cfg.window_pattern)) or (0,)
    if len(distinct) == 1:
        def body(carry, bp):
            x, aux = carry
            x, a = _block_apply_train(cfg, bp, x, int(distinct[0]), rope)
            return (x, aux + a), None
        body = _maybe_remat(body, cfg.remat)
        (x, aux), _ = scan_util.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   params["blocks"])
    else:
        # Alternating pattern: scan over layer *pairs* (gemma2: local,global)
        pat = cfg.window_pattern
        npat = len(pat)
        assert cfg.n_layers % npat == 0, (cfg.n_layers, pat)
        grouped = jax.tree.map(
            lambda a: a.reshape((cfg.n_layers // npat, npat) + a.shape[1:]),
            params["blocks"])

        def body(carry, bps):
            x, aux = carry
            for j, w in enumerate(pat):
                bp = jax.tree.map(lambda a: a[j], bps)
                x, a = _block_apply_train(cfg, bp, x, int(w), rope)
                aux = aux + a
            return (x, aux), None
        body = _maybe_remat(body, cfg.remat)
        (x, aux), _ = scan_util.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   grouped)
    logits = _logits(cfg, params, x)
    return logits, aux


# ------------------------------------------------------------- serving ---

class LayerCaches(NamedTuple):
    """Stacked per-layer caches (leading axis = layer)."""
    kv: Optional[KVCache]
    ssm: Optional[SSMCache]


def init_caches(cfg: ModelConfig, batch: int, max_s: int,
                dtype=jnp.bfloat16) -> LayerCaches:
    L = cfg.n_layers
    kv = ssm = None
    if cfg.family in ("dense", "moe", "encdec", "vlm"):
        one = attention.init_cache(cfg.attn_cfg, batch, max_s, dtype)
        kv = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (L,) + a.shape).copy(), one)
    if cfg.family == "ssm":
        one = ssm_lib.init_cache(cfg.ssm, batch, dtype)
        ssm = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (L,) + a.shape).copy(), one)
    return LayerCaches(kv=kv, ssm=ssm)


def _block_apply_decode(cfg: ModelConfig, bp: Params, x, window, rope,
                        kv: Optional[KVCache], sc: Optional[SSMCache]):
    if cfg.family == "ssm":
        h = layers.rmsnorm(bp["pre_norm"], x, plus_one=cfg.norm_plus_one)
        out, sc = ssm_lib.apply_decode(bp["mixer"], cfg.ssm, h, sc)
        return x + out, kv, sc
    h = layers.rmsnorm(bp["pre_attn_norm"], x, plus_one=cfg.norm_plus_one)
    acfg = cfg.attn_cfg._replace(window=int(window))
    attn_out, kv = attention.apply_decode(bp["attn"], acfg, h, kv, rope=rope)
    if cfg.post_norms:
        attn_out = layers.rmsnorm(bp["post_attn_norm"], attn_out,
                                  plus_one=cfg.norm_plus_one)
    x = x + attn_out
    h = layers.rmsnorm(bp["pre_mlp_norm"], x, plus_one=cfg.norm_plus_one)
    if cfg.moe is not None:
        mo, _ = moe_lib.apply(bp["moe"], cfg.moe, h)
        if cfg.dense_residual:
            mo = mo + layers.glu_mlp(bp["mlp"], h, cfg.activation)
        mlp_out = mo
    elif cfg.activation_is_glu:
        act = "silu" if cfg.activation == "silu" else "gelu"
        mlp_out = layers.glu_mlp(bp["mlp"], h, act)
    else:
        mlp_out = layers.mlp(bp["mlp"], h)
    if cfg.post_norms:
        mlp_out = layers.rmsnorm(bp["post_mlp_norm"], mlp_out,
                                 plus_one=cfg.norm_plus_one)
    return x + mlp_out, kv, sc


def apply_decode(params: Params, cfg: ModelConfig, tokens: jax.Array,
                 caches: LayerCaches) -> Tuple[jax.Array, LayerCaches]:
    """One-token decode: tokens (B, 1) → (logits (B, 1, V), new caches)."""
    x = layers.embed(params["embed"], tokens)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    max_s = caches.kv.k.shape[2] if caches.kv is not None else cfg.max_seq
    rope = _rope_tables(cfg, max_s)
    windows = cfg.layer_windows()

    def body(x, scanned):
        bp, w, kv, sc = scanned
        # window must be static for masking math; decode mask uses dynamic
        # comparison so traced w is fine here.
        if cfg.family == "ssm":
            xo, _, sc2 = _block_apply_decode(cfg, bp, x, 0, rope, None, sc)
            return xo, (None, sc2)
        acfg = cfg.attn_cfg
        h = layers.rmsnorm(bp["pre_attn_norm"], x,
                           plus_one=cfg.norm_plus_one)
        attn_out, kv2 = _decode_attn_dynwin(bp["attn"], acfg, h, kv, rope, w)
        if cfg.post_norms:
            attn_out = layers.rmsnorm(bp["post_attn_norm"], attn_out,
                                      plus_one=cfg.norm_plus_one)
        x = x + attn_out
        h = layers.rmsnorm(bp["pre_mlp_norm"], x, plus_one=cfg.norm_plus_one)
        if cfg.moe is not None:
            mo, _ = moe_lib.apply(bp["moe"], cfg.moe, h)
            if cfg.dense_residual:
                mo = mo + layers.glu_mlp(bp["mlp"], h, cfg.activation)
            mlp_out = mo
        elif cfg.activation_is_glu:
            act = "silu" if cfg.activation == "silu" else "gelu"
            mlp_out = layers.glu_mlp(bp["mlp"], h, act)
        else:
            mlp_out = layers.mlp(bp["mlp"], h)
        if cfg.post_norms:
            mlp_out = layers.rmsnorm(bp["post_mlp_norm"], mlp_out,
                                     plus_one=cfg.norm_plus_one)
        return x + mlp_out, (kv2, None)

    def scan_body(x, scanned):
        out, new = body(x, scanned)
        return out, new

    scanned = (params["blocks"], windows,
               caches.kv if caches.kv is not None else None,
               caches.ssm if caches.ssm is not None else None)
    # lax.scan needs every scanned leaf to have the layer leading dim; the
    # None entries are passed through a closure instead.
    if cfg.family == "ssm":
        def sbody(x, sl):
            bp, sc = sl
            out, (_, sc2) = body(x, (bp, jnp.int32(0), None, sc))
            return out, sc2
        x, new_ssm = scan_util.scan(sbody, x, (params["blocks"], caches.ssm))
        new_caches = LayerCaches(kv=None, ssm=new_ssm)
    else:
        def abody(x, sl):
            bp, w, kv = sl
            out, (kv2, _) = body(x, (bp, w, kv, None))
            return out, kv2
        x, new_kv = scan_util.scan(
            abody, x, (params["blocks"], windows, caches.kv))
        new_caches = LayerCaches(kv=new_kv, ssm=None)
    logits = _logits(cfg, params, x)
    return logits, new_caches


def _decode_attn_dynwin(p, acfg: AttnConfig, h, kv: KVCache, rope, w):
    """Decode attention with a *traced* per-layer window size (gemma2's
    alternation rides through lax.scan as data)."""
    b = h.shape[0]
    pos = jnp.broadcast_to(kv.length, (b, 1))
    q, k, v = attention._project_qkv(p, acfg, h, pos, rope)
    idx = kv.length
    new_k = jax.lax.dynamic_update_slice(
        kv.k, k.astype(kv.k.dtype), (0, idx, 0, 0))
    new_v = jax.lax.dynamic_update_slice(
        kv.v, v.astype(kv.v.dtype), (0, idx, 0, 0))
    max_s = kv.k.shape[1]
    group = acfg.n_heads // acfg.n_kv_heads
    scale = acfg.query_pre_scale or acfg.head_dim ** -0.5
    # Compute at activation precision: the bf16 cache quantizes k/v
    # storage, but downcasting the fresh q or the softmax probabilities to
    # the cache dtype doubles the quantization error vs the teacher-forced
    # forward pass (the glm4_9b decode-drift bug).
    kq = jnp.repeat(new_k, group, axis=2)
    vq = jnp.repeat(new_v, group, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, kq.astype(q.dtype),
                        preferred_element_type=jnp.float32) * scale
    logits = layers.softcap(logits, acfg.logit_softcap)
    kpos = jnp.arange(max_s)
    mask = kpos[None, :] <= idx
    mask &= jnp.where(w > 0, kpos[None, :] > idx - w, True)
    logits = jnp.where(mask[None, None], logits, -1e30)
    pattn = jax.nn.softmax(logits, axis=-1)
    # P·V·Wo association order comes from the serving planner (trace-time
    # consult, amortised by the jit cache — see attention.pv_wo_output).
    proj = attention.pv_wo_output(pattn, vq, p["wo"], acfg.n_heads,
                                  acfg.head_dim, h.dtype)
    return proj, KVCache(new_k, new_v, idx + 1)


def apply_prefill(params: Params, cfg: ModelConfig, tokens: jax.Array,
                  caches: LayerCaches,
                  prefix_embeds: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, LayerCaches]:
    """Prefill: full-sequence forward that also fills the caches."""
    x = layers.embed(params["embed"], tokens)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    s = x.shape[1]
    rope = _rope_tables(cfg, max(s, cfg.max_seq if caches.kv is None
                                 else caches.kv.k.shape[2]))
    windows = cfg.layer_windows()

    if cfg.family == "ssm":
        def sbody(x, sl):
            bp, sc = sl
            h = layers.rmsnorm(bp["pre_norm"], x, plus_one=cfg.norm_plus_one)
            out, sc2 = ssm_lib.apply_prefill(bp["mixer"], cfg.ssm, h, sc)
            return x + out, sc2
        x, new_ssm = scan_util.scan(sbody, x, (params["blocks"], caches.ssm))
        logits = _logits(cfg, params, x)
        return logits, LayerCaches(kv=None, ssm=new_ssm)

    def make_abody(window: int):
        def abody(x, sl):
            bp, kv = sl
            acfg = cfg.attn_cfg._replace(window=window)
            h = layers.rmsnorm(bp["pre_attn_norm"], x,
                               plus_one=cfg.norm_plus_one)
            attn_out, (k, v) = attention.apply_train(
                bp["attn"], acfg, h, rope=rope, return_kv=True,
                differentiable=False)
            new_kv = KVCache(
                k=jax.lax.dynamic_update_slice(
                    kv.k, k.astype(kv.k.dtype), (0, 0, 0, 0)),
                v=jax.lax.dynamic_update_slice(
                    kv.v, v.astype(kv.v.dtype), (0, 0, 0, 0)),
                length=jnp.asarray(s, jnp.int32),
            )
            if cfg.post_norms:
                attn_out = layers.rmsnorm(bp["post_attn_norm"], attn_out,
                                          plus_one=cfg.norm_plus_one)
            x = x + attn_out
            h = layers.rmsnorm(bp["pre_mlp_norm"], x,
                               plus_one=cfg.norm_plus_one)
            if cfg.moe is not None:
                mo, _ = moe_lib.apply(bp["moe"], cfg.moe, h)
                if cfg.dense_residual:
                    mo = mo + layers.glu_mlp(bp["mlp"], h, cfg.activation)
                mlp_out = mo
            elif cfg.activation_is_glu:
                act = "silu" if cfg.activation == "silu" else "gelu"
                mlp_out = layers.glu_mlp(bp["mlp"], h, act)
            else:
                mlp_out = layers.mlp(bp["mlp"], h)
            if cfg.post_norms:
                mlp_out = layers.rmsnorm(bp["post_mlp_norm"], mlp_out,
                                         plus_one=cfg.norm_plus_one)
            return shard_seq(x + mlp_out), new_kv
        return abody

    distinct = tuple(dict.fromkeys(cfg.window_pattern)) or (0,)
    if len(distinct) == 1:
        x, new_kv = scan_util.scan(make_abody(int(distinct[0])), x,
                                 (params["blocks"], caches.kv))
    else:
        pat = cfg.window_pattern
        npat = len(pat)
        assert cfg.n_layers % npat == 0
        grouped_b = jax.tree.map(
            lambda a: a.reshape((cfg.n_layers // npat, npat) + a.shape[1:]),
            params["blocks"])
        grouped_c = jax.tree.map(
            lambda a: a.reshape((cfg.n_layers // npat, npat) + a.shape[1:]),
            caches.kv)

        def gbody(x, sl):
            bps, kvs = sl
            new = []
            for j, w in enumerate(pat):
                bp = jax.tree.map(lambda a: a[j], bps)
                kv = jax.tree.map(lambda a: a[j], kvs)
                x, kv2 = make_abody(int(w))(x, (bp, kv))
                new.append(kv2)
            return x, jax.tree.map(lambda *ys: jnp.stack(ys), *new)

        x, new_kv_g = scan_util.scan(gbody, x, (grouped_b, grouped_c))
        new_kv = jax.tree.map(
            lambda a: a.reshape((cfg.n_layers,) + a.shape[2:]), new_kv_g)
    logits = _logits(cfg, params, x)
    return logits, LayerCaches(kv=new_kv, ssm=None)
