"""GQA attention with full / flash / sliding-window variants + KV cache.

Three execution paths:
  * ``train``  — full sequence, causal (or bidirectional for encoders);
    uses the Pallas flash kernel when the sequence is block-divisible and
    flash is requested, else the masked-dense reference.
  * ``prefill`` — same as train but returns the KV cache.
  * ``decode`` — one new token against a cache: a dense (1, S) contraction;
    quadratic blocking is pointless here, so it is pure jnp (and is where
    the LAMP chain planner acts on the surrounding projections instead).

No torch-style module state: ``init`` returns (params, axes); ``apply_*``
are pure functions.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import scan_util
from .layers import Axes, Params, apply_rope, dense, dense_init, softcap


class AttnConfig(NamedTuple):
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    logit_softcap: float = 0.0      # gemma2: 50.0
    window: int = 0                 # 0 = global; >0 = sliding window
    causal: bool = True
    use_flash: bool = True
    query_pre_scale: Optional[float] = None  # gemma2 scales by head_dim**-.5


class KVCache(NamedTuple):
    k: jax.Array        # (B, max_s, Hkv, Dh)
    v: jax.Array        # (B, max_s, Hkv, Dh)
    length: jax.Array   # () int32 — tokens currently valid


def init(key: jax.Array, cfg: AttnConfig, dtype=jnp.float32
         ) -> Tuple[Params, Axes]:
    kq, kk, kv, ko = jax.random.split(key, 4)
    p: Params = {}
    a: Axes = {}
    p["wq"], a["wq"] = dense_init(
        kq, cfg.d_model, cfg.n_heads * cfg.head_dim, ("embed", "heads"),
        dtype)
    p["wk"], a["wk"] = dense_init(
        kk, cfg.d_model, cfg.n_kv_heads * cfg.head_dim, ("embed", "kv_heads"),
        dtype)
    p["wv"], a["wv"] = dense_init(
        kv, cfg.d_model, cfg.n_kv_heads * cfg.head_dim, ("embed", "kv_heads"),
        dtype)
    p["wo"], a["wo"] = dense_init(
        ko, cfg.n_heads * cfg.head_dim, cfg.d_model, ("heads", "embed"),
        dtype)
    return p, a


def _project_qkv(params: Params, cfg: AttnConfig, x: jax.Array,
                 positions: jax.Array, rope: Optional[Tuple]):
    from repro.sharding.context import shard_heads
    b, s, _ = x.shape
    q = dense(params["wq"], x).reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = dense(params["wk"], x).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = dense(params["wv"], x).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    # Megatron TP layout: heads sharded, sequence gathered (no-op when the
    # head count doesn't divide the model axis, or outside a mesh context).
    q = shard_heads(q)
    k = shard_heads(k)
    v = shard_heads(v)
    if rope is not None:
        cos, sin = rope
        q = apply_rope(q, cos, sin, positions)
        k = apply_rope(k, cos, sin, positions)
    return q, k, v


def _dense_attention(cfg: AttnConfig, q, k, v, *, q_offset=0) -> jax.Array:
    """Masked dense attention; q: (B,Sq,H,Dh), k/v: (B,Sk,Hkv,Dh)."""
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    group = h // k.shape[2]
    scale = cfg.query_pre_scale or dh ** -0.5
    kq = jnp.repeat(k, group, axis=2)
    vq = jnp.repeat(v, group, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, kq,
                        preferred_element_type=jnp.float32) * scale
    logits = softcap(logits, cfg.logit_softcap)
    qpos = q_offset + jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if cfg.causal:
        mask &= qpos >= kpos
    if cfg.window > 0:
        mask &= qpos - kpos < cfg.window
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1).astype(vq.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vq)
    return out


def _attn_mask(sq, block, start, causal, window, qpos):
    kpos = start + jnp.arange(block)
    mask = jnp.ones((sq, block), dtype=bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window > 0:
        mask &= qpos[:, None] - kpos[None, :] < window
    return mask


def _chunked_fwd_scan(q, kb, vb, starts, *, scale, causal, window,
                      logit_softcap, block):
    b, sq, h, dh = q.shape
    qf = jnp.swapaxes(q.astype(jnp.float32), 1, 2)   # (B,H,Sq,Dh)
    qpos = jnp.arange(sq)

    def body(carry, inp):
        acc, m_prev, l_prev = carry
        kblk, vblk, start = inp
        s = jnp.einsum("bhqd,bkhd->bhqk", qf, kblk.astype(jnp.float32)
                       ) * scale
        s = softcap(s, logit_softcap)
        mask = _attn_mask(sq, block, start, causal, window, qpos)
        s = jnp.where(mask[None, None], s, -1e30)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vblk.astype(jnp.float32))
        return (acc, m_new, l_new), None

    acc0 = jnp.zeros((b, h, sq, dh), jnp.float32)
    m0 = jnp.full((b, h, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    (acc, m, l), _ = scan_util.scan(body, (acc0, m0, l0), (kb, vb, starts))
    l = jnp.maximum(l, 1e-30)
    out = acc / l[..., None]
    lse = m + jnp.log(l)
    return out, lse                                   # (B,H,Sq,Dh), (B,H,Sq)


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7))
def _chunked_core(q, kq, vq, scale, causal, window, logit_softcap, block):
    """Flash-style attention with a hand-written VJP: the backward pass
    recomputes P blockwise from (q, k, v, lse) instead of saving the S×S
    probability tensor — O(S·block) memory in both directions. Softcap is
    supported in forward-only paths; the VJP assumes softcap == 0 (gemma2
    training uses the dense path below the chunk threshold)."""
    b, sq, h, dh = q.shape
    nb = kq.shape[1] // block
    kb = jnp.moveaxis(kq.reshape(b, nb, block, h, dh), 1, 0)
    vb = jnp.moveaxis(vq.reshape(b, nb, block, h, dh), 1, 0)
    starts = jnp.arange(nb) * block
    out, _ = _chunked_fwd_scan(q, kb, vb, starts, scale=scale,
                               causal=causal, window=window,
                               logit_softcap=logit_softcap, block=block)
    return jnp.swapaxes(out, 1, 2).astype(q.dtype)    # (B,Sq,H,Dh)


def _chunked_core_fwd(q, kq, vq, scale, causal, window, logit_softcap,
                      block):
    b, sq, h, dh = q.shape
    nb = kq.shape[1] // block
    kb = jnp.moveaxis(kq.reshape(b, nb, block, h, dh), 1, 0)
    vb = jnp.moveaxis(vq.reshape(b, nb, block, h, dh), 1, 0)
    starts = jnp.arange(nb) * block
    out, lse = _chunked_fwd_scan(q, kb, vb, starts, scale=scale,
                                 causal=causal, window=window,
                                 logit_softcap=logit_softcap, block=block)
    res = (q, kq, vq, out, lse)
    return jnp.swapaxes(out, 1, 2).astype(q.dtype), res


def _chunked_core_bwd(scale, causal, window, logit_softcap, block,
                      res, g):
    q, kq, vq, out, lse = res
    b, sq, h, dh = q.shape
    nb = kq.shape[1] // block
    qf = jnp.swapaxes(q.astype(jnp.float32), 1, 2)     # (B,H,Sq,Dh)
    gf = jnp.swapaxes(g.astype(jnp.float32), 1, 2)     # (B,H,Sq,Dh)
    kb = jnp.moveaxis(kq.reshape(b, nb, block, h, dh), 1, 0)
    vb = jnp.moveaxis(vq.reshape(b, nb, block, h, dh), 1, 0)
    starts = jnp.arange(nb) * block
    qpos = jnp.arange(sq)
    # D_i = Σ_d dout_i · out_i  (flash backward identity)
    delta = jnp.sum(gf * out, axis=-1)                 # (B,H,Sq)

    def body(dq, inp):
        kblk, vblk, start = inp
        kf = kblk.astype(jnp.float32)
        vf = vblk.astype(jnp.float32)
        s = jnp.einsum("bhqd,bkhd->bhqk", qf, kf) * scale
        if logit_softcap > 0:
            t = jnp.tanh(s / logit_softcap)
            s_used = logit_softcap * t
        else:
            s_used = s
        mask = _attn_mask(sq, block, start, causal, window, qpos)
        s_used = jnp.where(mask[None, None], s_used, -1e30)
        p = jnp.exp(s_used - lse[..., None])           # (B,H,Sq,block)
        dv = jnp.einsum("bhqk,bhqd->bkhd", p, gf)
        dp = jnp.einsum("bhqd,bkhd->bhqk", gf, vf)
        ds = p * (dp - delta[..., None])               # ∂L/∂s_used
        if logit_softcap > 0:
            ds = ds * (1.0 - t * t)                    # softcap chain rule
        ds = ds * scale
        dq = dq + jnp.einsum("bhqk,bkhd->bhqd", ds, kf)
        dk = jnp.einsum("bhqk,bhqd->bkhd", ds, qf)
        # Per-block dk/dv in bf16: under sequence parallelism these partial
        # sums cross the model axis (all-reduce) — halving their width
        # halves the dominant attention-backward collective (§Perf).
        return dq, (dk.astype(jnp.bfloat16), dv.astype(jnp.bfloat16))

    dq0 = jnp.zeros_like(qf)
    dq, (dks, dvs) = scan_util.scan(body, dq0, (kb, vb, starts))
    dq = jnp.swapaxes(dq, 1, 2).astype(q.dtype)
    dk = jnp.moveaxis(dks, 0, 1).reshape(b, nb * block, h, dh)
    dv = jnp.moveaxis(dvs, 0, 1).reshape(b, nb * block, h, dh)
    return dq, dk.astype(kq.dtype), dv.astype(vq.dtype)


_chunked_core.defvjp(_chunked_core_fwd, _chunked_core_bwd)


def chunked_attention(cfg: AttnConfig, q, k, v, block: int = 512
                      ) -> jax.Array:
    """Flash-style attention with custom VJP (O(S·block) memory fwd+bwd).

    The autodiff-able counterpart of the Pallas flash kernel; XLA fuses the
    scan body into a flash-like schedule on TPU. GQA heads are broadcast
    (repeat) before the core; gradient flows back through the repeat to the
    shared KV heads automatically.
    """
    from repro.sharding.context import shard_heads
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    assert sk % block == 0, (sk, block)
    group = h // k.shape[2]
    scale = cfg.query_pre_scale or dh ** -0.5
    kq = shard_heads(jnp.repeat(k, group, axis=2))   # (B, Sk, H, Dh)
    vq = shard_heads(jnp.repeat(v, group, axis=2))
    return _chunked_core(q, kq, vq, scale, cfg.causal, cfg.window,
                         cfg.logit_softcap, block)


# Sequence length above which training uses the chunked (flash-style)
# attention instead of materializing the S×S logits.
CHUNKED_THRESHOLD = 2048


def apply_train(params: Params, cfg: AttnConfig, x: jax.Array,
                rope: Optional[Tuple] = None,
                positions: Optional[jax.Array] = None,
                return_kv: bool = False,
                differentiable: bool = True):
    """Full-sequence attention (training / prefill compute).

    ``differentiable=False`` (inference prefill) routes through the Pallas
    flash kernel; training uses the chunked scan (has a VJP) above the
    memory threshold, dense below it.
    """
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = _project_qkv(params, cfg, x, positions, rope)
    use_flash = (not differentiable and cfg.use_flash
                 and s % 128 == 0 and s >= 256)
    if use_flash:
        from repro.kernels import ops as kops
        scale = cfg.query_pre_scale or cfg.head_dim ** -0.5
        out = kops.flash_attention(
            q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2),
            causal=cfg.causal, scale=scale,
            logit_softcap=cfg.logit_softcap, window=cfg.window,
        ).swapaxes(1, 2)
    elif s >= CHUNKED_THRESHOLD and s % 512 == 0:
        out = chunked_attention(cfg, q, k, v)
    else:
        out = _dense_attention(cfg, q, k, v)
    out = out.reshape(b, s, cfg.n_heads * cfg.head_dim)
    proj = dense(params["wo"], out)
    if return_kv:
        return proj, (k, v)
    return proj


def init_cache(cfg: AttnConfig, batch: int, max_s: int,
               dtype=jnp.bfloat16) -> KVCache:
    shape = (batch, max_s, cfg.n_kv_heads, cfg.head_dim)
    return KVCache(
        k=jnp.zeros(shape, dtype),
        v=jnp.zeros(shape, dtype),
        length=jnp.zeros((), jnp.int32),
    )


def apply_prefill(params: Params, cfg: AttnConfig, x: jax.Array,
                  cache: KVCache, rope: Optional[Tuple] = None
                  ) -> Tuple[jax.Array, KVCache]:
    b, s, _ = x.shape
    proj, (k, v) = apply_train(params, cfg, x, rope=rope, return_kv=True,
                               differentiable=False)
    new_cache = KVCache(
        k=jax.lax.dynamic_update_slice(
            cache.k, k.astype(cache.k.dtype), (0, 0, 0, 0)),
        v=jax.lax.dynamic_update_slice(
            cache.v, v.astype(cache.v.dtype), (0, 0, 0, 0)),
        length=jnp.asarray(s, jnp.int32),
    )
    return proj, new_cache


def planned_pv_right_first(t: int, s: int, head_dim: int,
                           d_model: int) -> bool:
    """Trace-time planner consult: associate decode P·V·Wo right-first?

    The decode value→output tail is a genuine 3-matrix chain per head —
    P (t×s) · V (s×head_dim) · Wo (head_dim×d_model), the ``decattn`` zoo
    family — with two association orders. This asks the serving plan
    cache (:mod:`repro.serve.plan_cache`) which order the configured
    discriminant ranks first. It runs at *trace* time (shapes here are
    static Python ints), so under ``jax.jit`` the consult is amortised by
    the XLA compile cache: zero per-token cost.

    Selection must never take down the serving path: any failure (or the
    ``REPRO_SERVE_PLANNER=0`` kill-switch) falls back to the left
    association the pre-planner code always used. For realistic decode
    geometries (t=1, head_dim ≤ d_model) every shipped policy picks left
    — right costs s·head_dim·d_model multiply–adds per head vs left's
    s·head_dim — so the consult leaves decode numerics alone there; only
    shapes where a cost model genuinely prefers right (e.g. quantization
    effects on degenerate head_dim > d_model layouts, or wide
    speculative-decoding chunks) switch, and both orders are allclose up
    to float reassociation.
    """
    try:
        from repro.serve.plan_cache import (
            default_plan_service, planner_enabled)
        if not planner_enabled():
            return False
        plan = default_plan_service().lookup(
            "decattn", (t, s, head_dim, d_model))
        first = plan.algorithm.calls[0]
        # Right-first iff the first GEMM is V·Wo (its rows are the s axis).
        return s != t and first.dims[0] == s
    except Exception:
        return False


def pv_wo_output(p_attn: jax.Array, vq: jax.Array, wo_params: Params,
                 n_heads: int, head_dim: int, out_dtype) -> jax.Array:
    """Decode value→output tail with planner-chosen association order.

    ``p_attn`` (B, H, 1, K) are the softmax probabilities, ``vq``
    (B, K, H, head_dim) the head-expanded cached values; returns the
    projected output (B, 1, d_model). Left association is the classic
    ``(P·V)·Wo``; right reshapes Wo to (H, head_dim, d_model) and applies
    it per head first. Both orders contract the same operands, so the
    result is identical up to float reassociation.
    """
    b = p_attn.shape[0]
    d_model = wo_params["w"].shape[1]
    s = vq.shape[1]
    if planned_pv_right_first(1, s, head_dim, d_model):
        wo3 = wo_params["w"].astype(p_attn.dtype).reshape(
            n_heads, head_dim, d_model)
        vwo = jnp.einsum("bkhd,hde->bkhe", vq.astype(p_attn.dtype), wo3)
        out = jnp.einsum("bhqk,bkhe->bqe", p_attn, vwo)
        return out.astype(out_dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", p_attn, vq.astype(p_attn.dtype))
    out = out.reshape(b, 1, n_heads * head_dim)
    return dense(wo_params, out.astype(out_dtype))


def apply_decode(params: Params, cfg: AttnConfig, x: jax.Array,
                 cache: KVCache, rope: Optional[Tuple] = None
                 ) -> Tuple[jax.Array, KVCache]:
    """One-token step: x (B, 1, d). Cache updated in place at ``length``.

    The value→output tail P·V·Wo routes through :func:`pv_wo_output`,
    whose association order is chosen by the serving planner at trace
    time (see docs/serving.md)."""
    b, s1, _ = x.shape
    assert s1 == 1
    pos = jnp.broadcast_to(cache.length, (b, 1))
    q, k, v = _project_qkv(params, cfg, x, pos, rope)
    idx = cache.length
    new_k = jax.lax.dynamic_update_slice(
        cache.k, k.astype(cache.k.dtype), (0, idx, 0, 0))
    new_v = jax.lax.dynamic_update_slice(
        cache.v, v.astype(cache.v.dtype), (0, idx, 0, 0))
    max_s = cache.k.shape[1]
    group = cfg.n_heads // cfg.n_kv_heads
    scale = cfg.query_pre_scale or cfg.head_dim ** -0.5

    # The cache quantizes *storage* only (bf16 k/v): the contraction runs
    # at activation precision. Downcasting the fresh q or the softmax
    # probabilities to the cache dtype would double the quantization error
    # and drift decode logits away from the teacher-forced forward pass.
    kq = jnp.repeat(new_k, group, axis=2)   # (B, max_s, H, Dh)
    vq = jnp.repeat(new_v, group, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, kq.astype(q.dtype),
                        preferred_element_type=jnp.float32) * scale
    logits = softcap(logits, cfg.logit_softcap)
    kpos = jnp.arange(max_s)
    mask = kpos[None, :] <= idx
    if cfg.window > 0:
        mask &= kpos[None, :] > idx - cfg.window
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    proj = pv_wo_output(p, vq, params["wo"], cfg.n_heads, cfg.head_dim,
                        x.dtype)
    return proj, KVCache(new_k, new_v, idx + 1)


def apply_cross(params: Params, cfg: AttnConfig, x: jax.Array,
                enc_k: jax.Array, enc_v: jax.Array) -> jax.Array:
    """Cross-attention against precomputed encoder K/V (whisper decoder)."""
    b, s, _ = x.shape
    q = dense(params["wq"], x).reshape(b, s, cfg.n_heads, cfg.head_dim)
    cfg_nc = cfg._replace(causal=False, window=0)
    out = _dense_attention(cfg_nc, q, enc_k, enc_v)
    out = out.reshape(b, s, cfg.n_heads * cfg.head_dim)
    return dense(params["wo"], out)


def project_kv(params: Params, cfg: AttnConfig, enc: jax.Array):
    """Precompute cross-attention K/V from encoder output."""
    b, s, _ = enc.shape
    k = dense(params["wk"], enc).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = dense(params["wv"], enc).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    return k, v
