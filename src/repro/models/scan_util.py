"""Scan wrapper with a cost-measurement unroll mode.

XLA's ``cost_analysis`` counts a ``while`` body ONCE regardless of trip
count, which silently breaks any FLOPs/bytes accounting over
``lax.scan``-stacked layers. All layer/KV-block scans in the model stack go
through :func:`scan` below; inside :func:`unrolled` (used by the dry-run's
depth-variant compiles) they become Python loops, so the compiled HLO has
no while ops and cost analysis is exact. Production/training compiles keep
the real ``lax.scan`` (O(1) compile cost, loop in HLO).
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any, Callable, Optional

import jax

_state = threading.local()


def _unroll() -> bool:
    return getattr(_state, "unroll", False)


@contextlib.contextmanager
def unrolled(enable: bool = True):
    prev = getattr(_state, "unroll", False)
    _state.unroll = enable
    try:
        yield
    finally:
        _state.unroll = prev


def _length_of(xs: Any) -> int:
    leaves = jax.tree.leaves(xs)
    if not leaves:
        raise ValueError("scan with no xs leaves needs explicit length")
    return leaves[0].shape[0]


def scan(body: Callable, init: Any, xs: Any, length: Optional[int] = None):
    """Drop-in for jax.lax.scan(body, init, xs) honoring the unroll mode."""
    if not _unroll():
        return jax.lax.scan(body, init, xs)
    n = length if length is not None else _length_of(xs)
    carry = init
    ys = []
    for i in range(n):
        sl = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, sl)
        ys.append(y)
    if ys and ys[0] is not None:
        stacked = jax.tree.map(
            lambda *zs: jax.numpy.stack(zs, axis=0), *ys)
    else:
        stacked = None
    return carry, stacked
