"""Pure-JAX model zoo for the assigned architectures.

No flax/haiku — parameters are plain pytrees (nested dicts of jax.Array),
paired with a parallel pytree of *logical axis names* consumed by
``repro.sharding`` to derive PartitionSpecs. Layer stacks are stacked along
axis 0 and applied with ``lax.scan`` for O(1) compile cost in depth.
"""

from . import attention, hybrid, layers, moe, ssm, transformer

__all__ = ["attention", "hybrid", "layers", "moe", "ssm", "transformer"]
