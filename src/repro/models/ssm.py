"""Mamba2 / SSD layer — with the paper's algorithm selection built in.

The SSD (state-space duality) layer is the cleanest in-model instance of the
paper's thesis: the *same* sequence transformation

    h_t = exp(Δt·A)·h_{t-1} + Δt·B_t xₜᵀ ,   y_t = C_t·h_t

admits two mathematically equivalent algorithms —

  * ``quadratic``  — materialize the (S×S) semiseparable kernel
    ``(C·Bᵀ ⊙ L)``; FLOPs ≈ 2·S²·(N+P) per head: cheap for short S;
  * ``chunked``    — intra-chunk quadratic + inter-chunk recurrence;
    FLOPs ≈ 2·S·Q·(N+P) + 4·S·N·P: linear in S.

The crossover depends on (S, N, P, Q) *and* on achieved kernel efficiency
(the chunked form's many small GEMMs quantize worse on the MXU) — i.e.
FLOP count alone mispredicts near the boundary, which is the paper's
anomaly phenomenon. ``select_ssd_mode`` scores both algorithms with
either the ``flops`` discriminant (paper baseline) or the ``perfmodel``
discriminant (paper's conclusion) using the same machinery as
:mod:`repro.core`.

Inter-chunk states are carried with ``lax.associative_scan`` (log-depth,
TPU friendly) rather than a serial scan.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.flops import gemm as gemm_call
from repro.core.perfmodel import AnalyticalTPUProfile, KernelProfile

from . import layers
from .layers import Axes, Params, dense, dense_init


class SSMConfig(NamedTuple):
    d_model: int
    d_inner: int          # = n_heads * head_dim (expand * d_model)
    n_heads: int
    head_dim: int
    n_groups: int
    d_state: int          # N
    conv_kernel: int = 4
    chunk: int = 128
    ssd_mode: str = "auto"   # auto | quadratic | chunked
    discriminant: str = "perfmodel"


class SSMCache(NamedTuple):
    conv: jax.Array      # (B, K-1, conv_channels)
    state: jax.Array     # (B, H, N, P)
    length: jax.Array    # () int32


# ------------------------------------------------- algorithm selection ---

def ssd_algorithm_calls(mode: str, s: int, n: int, p: int, q: int,
                        heads: int):
    """Approximate each SSD form as a bag of GEMM calls for the cost model.

    On TPU both forms lower to BATCHED einsums (heads × chunks are batch
    dims of a single fused kernel), so each step type is modeled as ONE
    call whose N dimension absorbs the batch — total FLOPs exact, overhead
    charged once per einsum. Modeling them as nc·heads separate kernels
    (the CPU-BLAS view) over-charges dispatch overhead ~4096× at
    (S=4096, Q=128, H=32) and flips the selection to the quadratic form —
    a mis-calibrated profile producing exactly the wrong-algorithm anomaly
    the paper studies (§Perf-3, iteration 3).
    """
    if mode == "quadratic":
        return [gemm_call(s, s * heads, n), gemm_call(s, p * heads, s)]
    nc = max(1, s // q)
    batch = nc * heads
    return [
        gemm_call(q, q * batch, n),    # intra CBᵀ
        gemm_call(q, p * batch, q),    # intra (kernel)·X
        gemm_call(n, p * batch, q),    # chunk states  B·X
        gemm_call(q, p * batch, n),    # inter C·H
    ]


def select_ssd_mode(s: int, n: int, p: int, q: int, heads: int = 1,
                    discriminant: str = "perfmodel",
                    profile: Optional[KernelProfile] = None) -> str:
    """Choose the SSD algorithm with the paper's discriminants."""
    prof = profile or AnalyticalTPUProfile()
    scores = {}
    for mode in ("quadratic", "chunked"):
        calls = ssd_algorithm_calls(mode, s, n, p, q, heads)
        if discriminant == "flops":
            scores[mode] = sum(c.flops for c in calls)
        else:
            scores[mode] = sum(prof.time(c, 2) for c in calls)
    return min(scores, key=scores.get)


# ------------------------------------------------------------- the math ---

def _segsum_cumsum(da: jax.Array) -> jax.Array:
    """Cumulative log-decay along the time axis (axis=-2 convention:
    da shape (..., S, H)) — returns same shape."""
    return jnp.cumsum(da, axis=-2)


def ssd_quadratic(x, dt, a_log, bmat, cmat) -> jax.Array:
    """Dense semiseparable form. x:(B,S,H,P) dt:(B,S,H) a_log:(H,)
    bmat/cmat:(B,S,G,N). Returns (B,S,H,P)."""
    bsz, s, h, p = x.shape
    g = bmat.shape[2]
    rep = h // g
    a = -jnp.exp(a_log.astype(jnp.float32))          # (H,) negative
    da = dt.astype(jnp.float32) * a                  # (B,S,H)
    cum = jnp.cumsum(da, axis=1)                     # (B,S,H)
    # L[i,j] = exp(cum_i - cum_j), i >= j. Mask the EXPONENT (not the
    # product): exp of masked entries can overflow to inf and 0·inf → NaN
    # in the backward pass.
    diff = cum[:, :, None, :] - cum[:, None, :, :]   # (B,S,S,H)
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    L = jnp.exp(jnp.where(mask[None, :, :, None], diff, -1e30))
    bh = jnp.repeat(bmat, rep, axis=2).astype(jnp.float32)  # (B,S,H,N)
    ch = jnp.repeat(cmat, rep, axis=2).astype(jnp.float32)
    scores = jnp.einsum("bihn,bjhn->bijh", ch, bh)   # (B,S,S,H)
    kernel = scores * L
    xdt = x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]
    y = jnp.einsum("bijh,bjhp->bihp", kernel, xdt)
    return y.astype(x.dtype)


def ssd_chunked(x, dt, a_log, bmat, cmat, chunk: int,
                h0: Optional[jax.Array] = None,
                return_state: bool = False):
    """Chunked SSD. Shapes as ssd_quadratic; S % chunk == 0.

    ``h0`` (B,H,N,P) optional incoming state; ``return_state`` also returns
    the final state (for prefill→decode handoff).
    """
    bsz, s, h, p = x.shape
    g = bmat.shape[2]
    n = bmat.shape[3]
    rep = h // g
    q = chunk
    nc = s // q
    a = -jnp.exp(a_log.astype(jnp.float32))
    f32 = jnp.float32

    from repro.sharding.context import shard_ssd_chunks
    xc = shard_ssd_chunks(x.astype(f32).reshape(bsz, nc, q, h, p))
    dtc = shard_ssd_chunks(dt.astype(f32).reshape(bsz, nc, q, h))
    bc = shard_ssd_chunks(
        jnp.repeat(bmat, rep, axis=2).astype(f32).reshape(bsz, nc, q, h, n))
    cc = shard_ssd_chunks(
        jnp.repeat(cmat, rep, axis=2).astype(f32).reshape(bsz, nc, q, h, n))

    da = dtc * a                                     # (B,nc,Q,H)
    cum = jnp.cumsum(da, axis=2)                     # within-chunk cumsum
    total = cum[:, :, -1:, :]                        # (B,nc,1,H)

    # --- intra-chunk (quadratic within chunk) ---
    # Mask the exponent, not the product (0·inf → NaN in backward).
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,Q,Q,H)
    mask = jnp.tril(jnp.ones((q, q), dtype=bool))
    L = jnp.exp(jnp.where(mask[None, None, :, :, None], diff, -1e30))
    scores = jnp.einsum("bcihn,bcjhn->bcijh", cc, bc)
    xdt = xc * dtc[..., None]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores * L, xdt)

    # --- chunk states ---
    from repro.sharding.context import shard_ssd_states
    decay_to_end = jnp.exp(total - cum)              # (B,nc,Q,H)
    s_c = jnp.einsum("bcqhn,bcqhp->bchnp", bc * (decay_to_end * dtc)[..., None],
                     xc)                             # (B,nc,H,N,P)
    s_c = shard_ssd_states(s_c, h_axis=2)
    chunk_decay = jnp.exp(total[:, :, 0, :])         # (B,nc,H)

    # --- inter-chunk associative scan: H_c = d_c · H_{c-1} + S_c ---
    def combine(left, right):
        d1, s1 = left
        d2, s2 = right
        return d1 * d2, s1 * d2[..., None, None] + s2

    d_seq = jnp.moveaxis(chunk_decay, 1, 0)          # (nc,B,H)
    s_seq = shard_ssd_states(jnp.moveaxis(s_c, 1, 0), h_axis=2)
    if h0 is not None:
        # Fold the incoming state into the first chunk's emitted state.
        d_seq = jnp.concatenate([jnp.ones_like(d_seq[:1]), d_seq], axis=0)
        s_seq = jnp.concatenate([h0.astype(f32)[None], s_seq], axis=0)
    dd, hh = jax.lax.associative_scan(combine, (d_seq, s_seq), axis=0)
    if h0 is not None:
        hh = hh[1:]
    # states *entering* each chunk: shift right, zero (or h0) first.
    first = (h0.astype(f32) if h0 is not None
             else jnp.zeros_like(hh[0]))
    h_prev = jnp.concatenate([first[None], hh[:-1]], axis=0)
    h_prev = jnp.moveaxis(h_prev, 0, 1)              # (B,nc,H,N,P)

    y_inter = jnp.einsum("bcqhn,bchnp->bcqhp",
                         cc * jnp.exp(cum)[..., None], h_prev)

    y = (y_intra + y_inter).reshape(bsz, s, h, p).astype(x.dtype)
    if return_state:
        final = jnp.moveaxis(hh[-1:], 0, 1)[:, 0]    # (B,H,N,P)
        return y, final.astype(x.dtype)
    return y


def ssd(x, dt, a_log, bmat, cmat, cfg: SSMConfig) -> jax.Array:
    s = x.shape[1]
    q = min(cfg.chunk, s)
    mode = cfg.ssd_mode
    if mode == "auto":
        mode = select_ssd_mode(
            s, cfg.d_state, cfg.head_dim, q,
            heads=cfg.n_heads, discriminant=cfg.discriminant)
    if mode == "quadratic" or s % q != 0:
        return ssd_quadratic(x, dt, a_log, bmat, cmat)
    return ssd_chunked(x, dt, a_log, bmat, cmat, q)


# ------------------------------------------------------------- the block ---

def init(key: jax.Array, cfg: SSMConfig, dtype=jnp.float32
         ) -> Tuple[Params, Axes]:
    kin, kout, kdt, kconv = jax.random.split(key, 4)
    d = cfg.d_model
    di = cfg.d_inner
    gn = cfg.n_groups * cfg.d_state
    proj_out = 2 * di + 2 * gn + cfg.n_heads
    conv_ch = di + 2 * gn

    p: Params = {}
    a: Axes = {}
    p["in_proj"], a["in_proj"] = dense_init(
        kin, d, proj_out, ("embed", "inner"), dtype)
    p["out_proj"], a["out_proj"] = dense_init(
        kout, di, d, ("inner", "embed"), dtype)
    p["conv_w"] = jax.random.normal(
        kconv, (cfg.conv_kernel, conv_ch), dtype) * (cfg.conv_kernel ** -0.5)
    a["conv_w"] = ("conv_k", "inner")
    p["conv_b"] = jnp.zeros((conv_ch,), dtype)
    a["conv_b"] = ("inner",)
    p["a_log"] = jnp.log(jnp.linspace(1.0, 16.0, cfg.n_heads).astype(dtype))
    a["a_log"] = ("heads",)
    p["dt_bias"] = jnp.log(jnp.expm1(
        jnp.exp(jax.random.uniform(
            kdt, (cfg.n_heads,), dtype,
            minval=math.log(1e-3), maxval=math.log(1e-1)))))
    a["dt_bias"] = ("heads",)
    p["d_skip"] = jnp.ones((cfg.n_heads,), dtype)
    a["d_skip"] = ("heads",)
    p["norm"], a["norm"] = layers.rmsnorm_init(di, dtype)
    return p, a


def _causal_conv(seq: jax.Array, w: jax.Array, b: jax.Array,
                 prev: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv. seq (B,S,C), w (K,C). ``prev`` (B,K-1,C)
    supplies left context for decode."""
    k = w.shape[0]
    if prev is None:
        prev = jnp.zeros((seq.shape[0], k - 1, seq.shape[2]), seq.dtype)
    full = jnp.concatenate([prev, seq], axis=1)
    out = jnp.zeros_like(seq, dtype=jnp.float32)
    for i in range(k):
        out = out + full[:, i:i + seq.shape[1], :].astype(jnp.float32) \
            * w[i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(seq.dtype)


def _split_proj(cfg: SSMConfig, zxbcdt: jax.Array):
    di, gn, h = cfg.d_inner, cfg.n_groups * cfg.d_state, cfg.n_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * gn]
    dt = zxbcdt[..., di + di + 2 * gn:]
    return z, xbc, dt


def apply_train(params: Params, cfg: SSMConfig, u: jax.Array) -> jax.Array:
    """u: (B, S, d_model) → (B, S, d_model)."""
    bsz, s, _ = u.shape
    zxbcdt = dense(params["in_proj"], u)
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)
    xbc = jax.nn.silu(_causal_conv(xbc, params["conv_w"], params["conv_b"]))
    di, gn = cfg.d_inner, cfg.n_groups * cfg.d_state
    x = xbc[..., :di].reshape(bsz, s, cfg.n_heads, cfg.head_dim)
    bmat = xbc[..., di:di + gn].reshape(bsz, s, cfg.n_groups, cfg.d_state)
    cmat = xbc[..., di + gn:].reshape(bsz, s, cfg.n_groups, cfg.d_state)
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    y = ssd(x, dt, params["a_log"], bmat, cmat, cfg)
    y = y + x * params["d_skip"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(bsz, s, di)
    y = layers.rmsnorm(params["norm"], y * jax.nn.silu(z))
    return dense(params["out_proj"], y)


def init_cache(cfg: SSMConfig, batch: int, dtype=jnp.bfloat16) -> SSMCache:
    conv_ch = cfg.d_inner + 2 * cfg.n_groups * cfg.d_state
    return SSMCache(
        conv=jnp.zeros((batch, cfg.conv_kernel - 1, conv_ch), dtype),
        state=jnp.zeros(
            (batch, cfg.n_heads, cfg.d_state, cfg.head_dim), dtype),
        length=jnp.zeros((), jnp.int32),
    )


def apply_prefill(params: Params, cfg: SSMConfig, u: jax.Array,
                  cache: SSMCache) -> Tuple[jax.Array, SSMCache]:
    bsz, s, _ = u.shape
    zxbcdt = dense(params["in_proj"], u)
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)
    conv_tail = xbc[:, -(cfg.conv_kernel - 1):, :]
    xbc = jax.nn.silu(_causal_conv(xbc, params["conv_w"], params["conv_b"]))
    di, gn = cfg.d_inner, cfg.n_groups * cfg.d_state
    x = xbc[..., :di].reshape(bsz, s, cfg.n_heads, cfg.head_dim)
    bmat = xbc[..., di:di + gn].reshape(bsz, s, cfg.n_groups, cfg.d_state)
    cmat = xbc[..., di + gn:].reshape(bsz, s, cfg.n_groups, cfg.d_state)
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    y, final = ssd_chunked(x, dt, params["a_log"], bmat, cmat,
                           min(cfg.chunk, s), return_state=True)
    y = y + x * params["d_skip"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(bsz, s, di)
    y = layers.rmsnorm(params["norm"], y * jax.nn.silu(z))
    out = dense(params["out_proj"], y)
    return out, SSMCache(conv=conv_tail.astype(cache.conv.dtype),
                         state=final.astype(cache.state.dtype),
                         length=jnp.asarray(s, jnp.int32))


def apply_decode(params: Params, cfg: SSMConfig, u: jax.Array,
                 cache: SSMCache) -> Tuple[jax.Array, SSMCache]:
    """One-token step: O(1) in sequence length — why the SSM archs run the
    long_500k cell that dense attention cannot."""
    bsz, s1, _ = u.shape
    assert s1 == 1
    zxbcdt = dense(params["in_proj"], u)
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)
    new_conv = jnp.concatenate(
        [cache.conv, xbc.astype(cache.conv.dtype)], axis=1)[:, 1:, :]
    xbc = jax.nn.silu(_causal_conv(
        xbc, params["conv_w"], params["conv_b"],
        prev=cache.conv.astype(xbc.dtype)))
    di, gn = cfg.d_inner, cfg.n_groups * cfg.d_state
    x = xbc[..., :di].reshape(bsz, cfg.n_heads, cfg.head_dim)
    bmat = xbc[..., di:di + gn].reshape(bsz, cfg.n_groups, cfg.d_state)
    cmat = xbc[..., di + gn:].reshape(bsz, cfg.n_groups, cfg.d_state)
    rep = cfg.n_heads // cfg.n_groups
    bh = jnp.repeat(bmat, rep, axis=1).astype(jnp.float32)  # (B,H,N)
    ch = jnp.repeat(cmat, rep, axis=1).astype(jnp.float32)
    dt = jax.nn.softplus(
        dt_raw[:, 0].astype(jnp.float32)
        + params["dt_bias"].astype(jnp.float32))             # (B,H)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))        # (H,)
    decay = jnp.exp(dt * a)                                  # (B,H)
    xf = x.astype(jnp.float32)
    upd = jnp.einsum("bhn,bhp->bhnp", bh * dt[..., None], xf)
    state = cache.state.astype(jnp.float32) * decay[..., None, None] + upd
    y = jnp.einsum("bhn,bhnp->bhp", ch, state)               # (B,H,P)
    y = y + xf * params["d_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(bsz, 1, di).astype(u.dtype)
    y = layers.rmsnorm(params["norm"], y * jax.nn.silu(z))
    out = dense(params["out_proj"], y)
    return out, SSMCache(conv=new_conv,
                         state=state.astype(cache.state.dtype),
                         length=cache.length + 1)
