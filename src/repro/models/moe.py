"""Mixture-of-Experts layer: top-k router + capacity-based dispatch.

Two dispatch implementations, selectable via ``MoEConfig.dispatch``:

* ``gather`` (default, beyond-paper §Perf optimization) — GShard-style
  *grouped* dispatch with scatter/gather index plumbing: tokens are split
  into ``n_groups`` groups (sharded over the data axes); within each group
  capacity positions come from a local cumsum, and expert inputs/outputs
  move via ``take``/``segment`` gathers. Dispatch cost is pure data
  movement — no (T×E×C) one-hot einsum — and every intermediate is
  O(E·C_g·d) per group.
* ``einsum`` (reference) — the classic Shazeer one-hot dispatch/combine
  einsums. Mathematically identical under ample capacity; kept as the
  oracle the tests compare against, and as a worked example of why FLOPs
  blow up: the dispatch einsum alone costs T·d·E·C FLOPs (measured 100×
  the expert FLOPs at olmoe's train_4k cell — see EXPERIMENTS.md §Perf).

Tokens beyond an expert's per-group capacity are dropped (standard at
scale); the router adds the usual load-balancing auxiliary loss.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.sharding.context import shard_moe_groups

from .layers import Axes, Params, dense, dense_init


class MoEConfig(NamedTuple):
    d_model: int
    d_ff: int               # per-expert hidden
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    activation: str = "silu"
    dispatch: str = "gather"     # gather | einsum
    group_size: int = 4096       # tokens per dispatch group (gather mode)


def init(key: jax.Array, cfg: MoEConfig, dtype=jnp.float32
         ) -> Tuple[Params, Axes]:
    kr, kg, ku, kd = jax.random.split(key, 4)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    p: Params = {}
    a: Axes = {}
    p["router"], a["router"] = dense_init(kr, d, e, ("embed", "experts"),
                                          dtype)
    scale = d ** -0.5
    p["w_gate"] = jax.random.normal(kg, (e, d, f), dtype) * scale
    p["w_up"] = jax.random.normal(ku, (e, d, f), dtype) * scale
    p["w_down"] = jax.random.normal(kd, (e, f, d), dtype) * (f ** -0.5)
    a["w_gate"] = ("experts", "embed", "ffn")
    a["w_up"] = ("experts", "embed", "ffn")
    a["w_down"] = ("experts", "ffn", "embed")
    return p, a


def capacity(cfg: MoEConfig, n_tokens: int) -> int:
    cap = int(cfg.capacity_factor * n_tokens * cfg.top_k / cfg.n_experts)
    return max(cap, 1)


def _route(params: Params, cfg: MoEConfig, xt: jax.Array):
    """Shared router: returns (gate_vals (T,k), gate_idx (T,k), aux)."""
    e, k = cfg.n_experts, cfg.top_k
    nt = xt.shape[0]
    logits = dense(params["router"], xt).astype(jnp.float32)   # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)               # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)
    me = probs.mean(axis=0)
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)     # (T,k,E)
    ce = onehot.sum(axis=(0, 1)) / (nt * k)
    aux = e * jnp.sum(me * ce)
    return gate_vals, gate_idx, aux


# ------------------------------------------------------ einsum dispatch ---

def _apply_einsum(params: Params, cfg: MoEConfig, x: jax.Array
                  ) -> Tuple[jax.Array, jax.Array]:
    b, s, d = x.shape
    nt = b * s
    xt = x.reshape(nt, d)
    e, k = cfg.n_experts, cfg.top_k
    cap = capacity(cfg, nt)
    gate_vals, gate_idx, aux = _route(params, cfg, xt)

    flat_idx = gate_idx.reshape(-1)                             # (T*k,)
    onehot_flat = jax.nn.one_hot(flat_idx, e, dtype=jnp.int32)  # (T*k, E)
    pos_in_expert = (jnp.cumsum(onehot_flat, axis=0) - 1)       # (T*k, E)
    pos = jnp.take_along_axis(
        pos_in_expert, flat_idx[:, None], axis=1)[:, 0]         # (T*k,)
    keep = pos < cap
    gate_flat = gate_vals.reshape(-1) * keep.astype(jnp.float32)

    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, cap), cap + 1,
                            dtype=x.dtype)[..., :cap]           # (T*k, cap)
    disp = (onehot_flat.astype(x.dtype)[:, :, None] * pos_oh[:, None, :]
            ).reshape(nt, k, e, cap).sum(axis=1)                # (T,E,C)
    comb = (onehot_flat.astype(jnp.float32)
            * gate_flat[:, None])[:, :, None] * pos_oh[:, None, :].astype(
                jnp.float32)
    comb = comb.reshape(nt, k, e, cap).sum(axis=1)              # (T,E,C)

    xe = jnp.einsum("td,tec->ecd", xt, disp)
    act = jax.nn.silu if cfg.activation == "silu" else jax.nn.gelu
    h = act(jnp.einsum("ecd,edf->ecf", xe, params["w_gate"].astype(x.dtype))
            ) * jnp.einsum("ecd,edf->ecf", xe,
                           params["w_up"].astype(x.dtype))
    ye = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(x.dtype))
    yt = jnp.einsum("ecd,tec->td", ye.astype(jnp.float32), comb)
    return yt.reshape(b, s, d).astype(x.dtype), aux


# ------------------------------------------------------ gather dispatch ---

def _apply_gather(params: Params, cfg: MoEConfig, x: jax.Array
                  ) -> Tuple[jax.Array, jax.Array]:
    """Grouped scatter/gather dispatch (GShard groups, zero-matmul)."""
    b, s, d = x.shape
    nt = b * s
    e, k = cfg.n_experts, cfg.top_k
    # group count: ~group_size tokens each, at least 1
    g = max(1, nt // max(cfg.group_size, 1))
    while nt % g:
        g -= 1
    tg = nt // g
    cap = capacity(cfg, tg)

    xt = x.reshape(nt, d)
    gate_vals, gate_idx, aux = _route(params, cfg, xt)

    xg = xt.reshape(g, tg, d)
    xg = shard_moe_groups(xg)
    eidx = gate_idx.reshape(g, tg, k)
    gval = gate_vals.reshape(g, tg, k)

    # positions within expert per group: cumsum over flattened (tg*k)
    ef = eidx.reshape(g, tg * k)
    onehot = jax.nn.one_hot(ef, e, dtype=jnp.int32)            # (g,tg*k,E)
    pos_all = jnp.cumsum(onehot, axis=1) - 1                   # (g,tg*k,E)
    pos = jnp.take_along_axis(pos_all, ef[:, :, None],
                              axis=2)[:, :, 0]                 # (g, tg*k)
    keep = pos < cap
    # slot id within group: e*cap + pos; dropped → overflow slot e*cap*...
    slot = jnp.where(keep, ef * cap + pos, e * cap)            # (g, tg*k)

    # scatter token index into slots: slot_src[g, slot] = flat token idx+1
    tok_local = jnp.broadcast_to(
        jnp.arange(tg * k, dtype=jnp.int32)[None] // k, (g, tg * k))
    slot_src = jnp.zeros((g, e * cap + 1), jnp.int32)
    slot_src = slot_src.at[
        jnp.arange(g)[:, None], slot].set(tok_local + 1)
    occupied = slot_src[:, : e * cap] > 0                      # (g, E*cap)
    src = jnp.maximum(slot_src[:, : e * cap] - 1, 0)           # (g, E*cap)

    # gather expert inputs: (g, E*cap, d) → (E, g*cap, d) token-major
    xe = jnp.take_along_axis(xg, src[:, :, None], axis=1)
    xe = xe * occupied[:, :, None].astype(xe.dtype)
    xe = xe.reshape(g, e, cap, d).transpose(1, 0, 2, 3) \
           .reshape(e, g * cap, d)

    act = jax.nn.silu if cfg.activation == "silu" else jax.nn.gelu
    wg = params["w_gate"].astype(x.dtype)
    wu = params["w_up"].astype(x.dtype)
    wd = params["w_down"].astype(x.dtype)
    h = act(jnp.einsum("ecd,edf->ecf", xe, wg)) \
        * jnp.einsum("ecd,edf->ecf", xe, wu)
    ye = jnp.einsum("ecf,efd->ecd", h, wd)                     # (E,g*cap,d)
    ye = ye.reshape(e, g, cap, d).transpose(1, 0, 2, 3) \
           .reshape(g, e * cap, d)

    # combine: per (token, choice) gather its slot's output
    safe_slot = jnp.where(keep, slot, 0)
    y_tk = jnp.take_along_axis(ye, safe_slot[:, :, None], axis=1)
    y_tk = y_tk * keep[:, :, None].astype(y_tk.dtype)          # (g,tg*k,d)
    y_tk = y_tk.reshape(g, tg, k, d) * gval[..., None].astype(y_tk.dtype)
    yg = jnp.sum(y_tk, axis=2)                                 # (g, tg, d)
    return yg.reshape(b, s, d).astype(x.dtype), aux


def apply(params: Params, cfg: MoEConfig, x: jax.Array
          ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) → (output, aux_loss)."""
    if cfg.dispatch == "einsum":
        return _apply_einsum(params, cfg, x)
    return _apply_gather(params, cfg, x)
