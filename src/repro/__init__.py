"""repro — a LAMP-aware multi-pod JAX training/serving framework.

Reproduction + productization of "FLOPs as a Discriminant for Dense Linear
Algebra Algorithms" (López, Karlsson, Bientinesi — ICPP'22): algorithm
selection for linear-algebra expressions as a first-class runtime feature
(repro.core), TPU Pallas kernels for the paper's BLAS set (repro.kernels),
and a production substrate (models/configs/data/optim/sharding/train/serve/
checkpoint/runtime/launch) that scales the idea to multi-pod meshes.
"""

__version__ = "1.0.0"
